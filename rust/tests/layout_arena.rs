//! Tile-major layout + unified ScratchArena + fused requant drain:
//! the refactor's safety net. Every execution path — fast (staged
//! kernel, stripe writes, fused stripe-staging reads), counted
//! reference (stripe writes through the arena SPE, same fused glue),
//! golden `forward` (the PRE-fusion reference: standalone
//! requant_slice drain + pad), and its fused arena twin
//! `forward_scratch` — must compute the identical integer function,
//! across seeds, stride edges, partial column stripes (`live < m`,
//! down to the ragged fixture's live=1), dense mode, and forced tile
//! parallelism; fused drains must charge the identical counters
//! (static == counted); and one arena must serve different-shaped
//! models back to back with zero stale-stripe bleed-through.

use va_accel::arch::ChipConfig;
use va_accel::compiler::compile;
use va_accel::data::{fixtures, Dataset, SplitMix64};
use va_accel::nn::{QLayer, QuantModel};
use va_accel::sim::{self, ScratchArena};
use va_accel::REC_LEN;

/// Random i8 recordings of `len` samples.
fn recordings(rng: &mut SplitMix64, n: usize, len: usize) -> Vec<Vec<i8>> {
    (0..n)
        .map(|_| (0..len)
            .map(|_| ((rng.next_u64() % 255) as i32 - 127) as i8)
            .collect())
        .collect()
}

/// All four paths agree on `xs`, with the sim paths sharing the two
/// given arenas (which deliberately carry state across calls — and
/// across MODELS, when the caller reuses them).
fn assert_all_paths_agree(model: &QuantModel,
                          cm: &va_accel::compiler::CompiledModel,
                          xs: &[Vec<i8>], fast_arena: &mut ScratchArena,
                          counted_arena: &mut ScratchArena, tag: &str) {
    for (i, x) in xs.iter().enumerate() {
        let golden = model.forward(x);
        assert_eq!(model.forward_scratch(x, fast_arena), golden,
                   "{tag}: forward_scratch, recording {i}");
        let fast = sim::run_scratch(cm, x, fast_arena);
        assert_eq!(fast.logits, golden, "{tag}: fast path, recording {i}");
        let counted = sim::run_counted_scratch(cm, x, counted_arena);
        assert_eq!(counted.logits, golden, "{tag}: counted, recording {i}");
        assert_eq!(fast.counters, counted.counters,
                   "{tag}: static != counted counters, recording {i}");
        let par = sim::run_parallel(cm, x);
        assert_eq!(par.logits, golden, "{tag}: parallel tiles, recording {i}");
        assert_eq!(par.counters, counted.counters,
                   "{tag}: parallel counters, recording {i}");
    }
}

#[test]
fn all_paths_agree_on_paper_shaped_fixture_seed_swept() {
    let mut rng = SplitMix64::new(0x7117E);
    for seed in [2u64, 0xCAFE, 0x5EED_CAB1] {
        let model = fixtures::quant_model(seed);
        let cm = compile(&model, &ChipConfig::paper_1d(), REC_LEN).unwrap();
        let mut fast = ScratchArena::for_model(&cm);
        let mut counted = ScratchArena::for_model(&cm);
        let xs = recordings(&mut rng, 2, REC_LEN);
        assert_all_paths_agree(&model, &cm, &xs, &mut fast, &mut counted,
                               &format!("seed {seed}"));
    }
}

#[test]
fn all_paths_agree_on_ragged_partial_stripes() {
    // every layer ends in a partial stripe (live < m, down to live 1);
    // padding lanes must contribute nothing and stripes must not bleed
    let mut rng = SplitMix64::new(0xA66ED);
    for seed in [1u64, 99, 0xBEE] {
        let model = fixtures::ragged_model(seed);
        let cm = compile(&model, &ChipConfig::paper_1d(),
                         fixtures::RAGGED_LEN).unwrap();
        let mut fast = ScratchArena::for_model(&cm);
        let mut counted = ScratchArena::for_model(&cm);
        let xs = recordings(&mut rng, 3, fixtures::RAGGED_LEN);
        assert_all_paths_agree(&model, &cm, &xs, &mut fast, &mut counted,
                               &format!("ragged seed {seed}"));
    }
}

#[test]
fn stride_edges_and_dense_mode() {
    // k == stride (zero padding), stride 1 with a wide kernel, a
    // fully-pruned lane, ragged cout — through sparse AND dense mode
    let model = QuantModel { layers: vec![
        QLayer { k: 2, stride: 2, cin: 1, cout: 5, relu: true, nbits: 4,
                 shift: 24, s_in: 1.0, s_out: 1.0,
                 w: vec![1, 0, -2, 3, 0,
                         0, 2, 0, -1, 0], // lane 4 fully pruned
                 bias: vec![1, 2, 3, 4, 5], m0: vec![1 << 22; 5] },
        QLayer { k: 3, stride: 1, cin: 5, cout: 2, relu: false, nbits: 8,
                 shift: 0, s_in: 1.0, s_out: 1.0,
                 w: (0..30).map(|i| if i % 3 == 0 { 0 } else { i - 15 }).collect(),
                 bias: vec![0, 0], m0: vec![0, 0] },
    ]};
    let mut rng = SplitMix64::new(0xD15E);
    let xs = recordings(&mut rng, 4, 16);
    for zero_skip in [true, false] {
        let mut cfg = ChipConfig::paper_1d();
        cfg.zero_skip = zero_skip;
        let cm = compile(&model, &cfg, 16).unwrap();
        let mut fast = ScratchArena::for_model(&cm);
        let mut counted = ScratchArena::for_model(&cm);
        assert_all_paths_agree(&model, &cm, &xs, &mut fast, &mut counted,
                               &format!("edges zero_skip={zero_skip}"));
    }
}

#[test]
fn one_arena_serves_different_shaped_models_without_bleed_through() {
    // Interleave two models of different geometry (different layer
    // counts, strides, couts, input lengths) through ONE arena per
    // path. Results must equal fresh-arena runs on every call — a
    // stale stripe, window stage, SPE counter, or oversized buffer
    // from the other model must never leak through.
    let a = fixtures::quant_model(0x1111);
    let b = fixtures::ragged_model(0x2222);
    let cm_a = compile(&a, &ChipConfig::paper_1d(), REC_LEN).unwrap();
    let cm_b = compile(&b, &ChipConfig::paper_1d(),
                       fixtures::RAGGED_LEN).unwrap();
    let mut rng = SplitMix64::new(0xB1EED);
    let xa = recordings(&mut rng, 3, REC_LEN);
    let xb = recordings(&mut rng, 3, fixtures::RAGGED_LEN);
    // shared arenas start sized for NEITHER model
    let mut fast = ScratchArena::new();
    let mut counted = ScratchArena::new();
    let mut golden = ScratchArena::new();
    for i in 0..3 {
        for (model, cm, x) in [(&a, &cm_a, &xa[i]), (&b, &cm_b, &xb[i]),
                               (&a, &cm_a, &xa[i])] {
            let want = sim::run(cm, x); // fresh arena reference
            let got = sim::run_scratch(cm, x, &mut fast);
            assert_eq!(got.logits, want.logits, "round {i}: fast bleed");
            assert_eq!(got.counters, want.counters, "round {i}");
            let counted_r = sim::run_counted_scratch(cm, x, &mut counted);
            assert_eq!(counted_r.logits, want.logits,
                       "round {i}: counted bleed");
            assert_eq!(counted_r.counters, want.counters,
                       "round {i}: counted counters");
            assert_eq!(model.forward_scratch(x, &mut golden), want.logits,
                       "round {i}: golden bleed");
        }
    }
}

#[test]
fn fused_staging_equals_prefusion_drain_then_pad_on_real_schedules() {
    // The fused stripe-staging read (`nn::pad_same_from_stripes` over
    // the schedule's carried `in_stripes`) must be bit-exact with the
    // PR3 two-pass composition — requant-drain the stripes to a
    // row-major [L, Cin] map, then `pad_same_into` — on every real
    // layer boundary of both fixtures, including the ragged model's
    // live=1 partial stripes and every stride/kernel edge the
    // geometries exercise. Stripe contents are synthetic (any i32
    // accumulator pattern must round-trip identically).
    for (model, len, tag) in [
        (fixtures::quant_model(0xFA5E), REC_LEN, "paper"),
        (fixtures::ragged_model(0xFA5E), fixtures::RAGGED_LEN, "ragged"),
    ] {
        let cm = compile(&model, &ChipConfig::paper_1d(), len).unwrap();
        let mut rng = SplitMix64::new(0xD4A1);
        for li in 1..cm.layers.len() {
            let layer = &cm.layers[li];
            let prev = &cm.layers[li - 1];
            let prod = &cm.schedule.layers[li - 1];
            let sched = &cm.schedule.layers[li];
            assert_eq!(sched.in_stripes, prod.stripes, "{tag} layer {li}");
            assert_eq!(sched.l_in, prod.lout, "{tag} layer {li}");
            let (l, cin) = (prod.lout, layer.cin);
            let out_prev: Vec<i32> = (0..prod.out_len)
                .map(|_| (rng.next_u64() as i32) >> 12)
                .collect();
            // pre-fusion composition
            let mut act = vec![0i32; l * cin];
            for st in &prod.stripes {
                let stripe = &out_prev[st.offset..st.offset + l * st.live];
                for (lo, row) in stripe.chunks_exact(st.live).enumerate() {
                    for (lane, &v) in row.iter().enumerate() {
                        act[lo * cin + st.base_co + lane] =
                            va_accel::nn::requant(v, prev.m0[st.base_co + lane],
                                                  prev.shift, prev.relu);
                    }
                }
            }
            let mut want = Vec::new();
            va_accel::nn::pad_same_into(&act, l, cin, layer.k, layer.stride,
                                        &mut want);
            // fused single pass, into a dirty reused buffer
            let mut got = vec![91i32; want.len() + 13];
            va_accel::nn::pad_same_from_stripes(
                &sched.in_stripes, &out_prev, l, cin, layer.k, layer.stride,
                &prev.m0, prev.shift, prev.relu, &mut got);
            assert_eq!(got, want, "{tag} layer {li}");
        }
    }
}

#[test]
fn fused_drains_charge_identical_counters_seed_swept() {
    // Fusing the drain into staging moves a software pass, not chip
    // events: the fast path's compile-time static counters must still
    // equal the dynamically counted reference (serial AND forced-
    // parallel) on every recording — across seeds, the ragged
    // partial-stripe fixture, and dense (zero-skip off) mode.
    let mut rng = SplitMix64::new(0x0FF5E7);
    for seed in [7u64, 0xD0D0] {
        for (model, len, tag) in [
            (fixtures::quant_model(seed), REC_LEN, "paper"),
            (fixtures::ragged_model(seed), fixtures::RAGGED_LEN, "ragged"),
        ] {
            for zero_skip in [true, false] {
                let mut cfg = ChipConfig::paper_1d();
                cfg.zero_skip = zero_skip;
                let cm = compile(&model, &cfg, len).unwrap();
                let mut fast = ScratchArena::for_model(&cm);
                let mut counted = ScratchArena::for_model(&cm);
                for (i, x) in recordings(&mut rng, 2, len).iter().enumerate() {
                    let f = sim::run_scratch(&cm, x, &mut fast);
                    let c = sim::run_counted_scratch(&cm, x, &mut counted);
                    assert_eq!(f.counters, c.counters,
                               "{tag} seed {seed} zs={zero_skip} rec {i}: \
                                static != counted");
                    let p = sim::run_parallel(&cm, x);
                    assert_eq!(p.counters, c.counters,
                               "{tag} seed {seed} zs={zero_skip} rec {i}: \
                                parallel != serial counters");
                    assert_eq!(f.logits, c.logits,
                               "{tag} seed {seed} zs={zero_skip} rec {i}");
                }
            }
        }
    }
}

#[test]
fn counted_scratch_equals_counted_fresh() {
    // run_counted (fresh arena per call) and run_counted_scratch over
    // one long-lived arena are the same function
    let model = fixtures::quant_model(0xC0DE);
    let cm = compile(&model, &ChipConfig::paper_1d(), REC_LEN).unwrap();
    let ds = Dataset::synthesize(29, 2, 0.5);
    let mut arena = ScratchArena::for_model(&cm);
    for (i, x) in ds.x.iter().enumerate() {
        let fresh = sim::run_counted(&cm, x);
        let reused = sim::run_counted_scratch(&cm, x, &mut arena);
        assert_eq!(fresh.logits, reused.logits, "recording {i}");
        assert_eq!(fresh.counters, reused.counters, "recording {i}");
        assert_eq!(fresh.predicted, reused.predicted, "recording {i}");
    }
}
