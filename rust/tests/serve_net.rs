//! End-to-end wire-protocol tests: a real `NetServer` on a loopback
//! port, real `TcpStream` clients, every edge the protocol documents —
//! malformed/oversized prefixes, truncation + half-close, bad auth,
//! BUSY backpressure, capacity/rate rejection, graceful drain, and
//! bit-exactness of streamed diagnoses vs the offline
//! [`StreamSession`] oracle.

use std::io::Write;
use std::net::{Shutdown, TcpStream};
use std::sync::{Arc, OnceLock};
use std::time::Duration;

use va_accel::arch::ChipConfig;
use va_accel::compiler::{compile, CompiledModel};
use va_accel::coordinator::{loadgen, loadgen_scenario, wire, DeviceClient,
                            NetServer, ResilientDevice, ServeConfig,
                            StreamSession};
use va_accel::data::fixtures;
use va_accel::data::scenarios::Family;
use va_accel::reliability::{FaultKind, FaultPlan, PlannedFault};
use va_accel::REC_LEN;

const TOKEN: &str = "test-token";

/// One compiled paper-shaped model shared by every test (compile once;
/// sessions clone nothing, they just reference it).
fn compiled() -> Arc<CompiledModel> {
    static CM: OnceLock<Arc<CompiledModel>> = OnceLock::new();
    Arc::clone(CM.get_or_init(|| {
        let m = fixtures::quant_model(0xC0FFEE);
        Arc::new(compile(&m, &ChipConfig::paper_1d(), REC_LEN).unwrap())
    }))
}

fn server(cfg: ServeConfig) -> NetServer {
    NetServer::spawn(cfg, compiled()).unwrap()
}

/// Deterministic pre-quantized device stream in ADC range.
fn qstream(seed: u64, n: usize) -> Vec<i8> {
    let mut rng = va_accel::data::SplitMix64::new(seed);
    (0..n).map(|_| ((rng.next_u64() % 255) as i64 - 127) as i8).collect()
}

/// Drive one chunk through the client in lockstep, absorbing BUSY
/// resends and stray STATS frames, returning the diagnoses received.
fn send_lockstep(client: &mut DeviceClient, chunk: &[i8],
                 expect_window: bool) -> Vec<[i32; 2]> {
    client.send_i8(chunk).unwrap();
    let mut got = Vec::new();
    if !expect_window {
        return got;
    }
    loop {
        match client.recv().unwrap() {
            wire::Frame::Diagnosis { logits, .. } => {
                got.push(logits);
                return got;
            }
            wire::Frame::Busy { .. } => {
                std::thread::sleep(Duration::from_micros(200));
                client.send_i8(chunk).unwrap();
            }
            wire::Frame::Stats { .. } => {}
            f => panic!("unexpected frame: {f:?}"),
        }
    }
}

#[test]
fn streamed_i8_session_is_bit_exact_vs_offline_oracle() {
    let hop = 128;
    let srv = server(ServeConfig::loopback(TOKEN, hop));
    let mut client =
        DeviceClient::connect(srv.local_addr(), TOKEN, 7).unwrap();
    assert_eq!(client.hop as usize, hop);
    assert_eq!(client.frame_len as usize, REC_LEN);
    client.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

    let windows = 6;
    let stream = qstream(42, REC_LEN + hop * (windows - 1));
    let mut got: Vec<[i32; 2]> = Vec::new();
    let mut sent = 0usize;
    for w in 0..windows {
        let chunk = if w == 0 { &stream[..REC_LEN] }
                    else { &stream[sent..sent + hop] };
        got.extend(send_lockstep(&mut client, chunk, true));
        sent += chunk.len();
    }
    client.finish().unwrap();
    let stats = srv.shutdown();

    let mut oracle = StreamSession::new(compiled(), hop).unwrap();
    let want: Vec<[i32; 2]> = oracle.push_quantized(&stream)
        .into_iter().map(|d| d.logits).collect();
    assert_eq!(got, want, "streamed diagnoses must be bit-exact");
    assert_eq!(stats.windows, windows as u64);
    assert_eq!(stats.conns, 0, "connection must be torn down");
}

#[test]
fn streamed_f32_session_is_bit_exact_vs_offline_oracle() {
    let hop = 256;
    let srv = server(ServeConfig::loopback(TOKEN, hop));
    let mut client =
        DeviceClient::connect(srv.local_addr(), TOKEN, 8).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

    // analog samples as f32 — the wire type — so client and oracle
    // quantize the identical f64 values (f32 as f64 is exact)
    let mut rng = va_accel::data::SplitMix64::new(99);
    let total = REC_LEN + hop * 2;
    let analog: Vec<f32> = (0..total).map(|_| rng.gauss() as f32).collect();

    let mut got: Vec<[i32; 2]> = Vec::new();
    for chunk in analog.chunks(REC_LEN) {
        client.send_f32(chunk).unwrap();
    }
    for _ in 0..3 {
        loop {
            match client.recv().unwrap() {
                wire::Frame::Diagnosis { logits, .. } => {
                    got.push(logits);
                    break;
                }
                wire::Frame::Stats { .. } | wire::Frame::Busy { .. } => {}
                f => panic!("unexpected frame: {f:?}"),
            }
        }
    }
    client.finish().unwrap();
    srv.shutdown();

    let mut oracle = StreamSession::new(compiled(), hop).unwrap();
    let raw: Vec<f64> = analog.iter().map(|&x| x as f64).collect();
    let want: Vec<[i32; 2]> = oracle.push(&raw)
        .into_iter().map(|d| d.logits).collect();
    assert_eq!(got, want);
}

#[test]
fn wrong_auth_token_is_rejected() {
    let srv = server(ServeConfig::loopback(TOKEN, 128));
    let err = DeviceClient::connect(srv.local_addr(), "letmein", 1)
        .unwrap_err();
    assert!(err.to_string().contains(&format!("code {}", wire::ERR_AUTH)),
            "{err}");
    let stats = srv.shutdown();
    assert_eq!(stats.rejected_auth, 1);
    assert_eq!(stats.sessions, 0);
}

#[test]
fn samples_before_hello_is_a_protocol_error() {
    let srv = server(ServeConfig::loopback(TOKEN, 128));
    let mut sock = TcpStream::connect(srv.local_addr()).unwrap();
    wire::write_frame(&mut sock, &wire::Frame::SamplesI8(vec![1, 2, 3]))
        .unwrap();
    sock.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    match wire::read_frame(&mut sock, wire::MAX_FRAME_BYTES).unwrap() {
        wire::Frame::Error { code, .. } =>
            assert_eq!(code, wire::ERR_PROTOCOL),
        f => panic!("expected ERROR, got {f:?}"),
    }
    let stats = srv.shutdown();
    assert_eq!(stats.protocol_errors, 1);
}

#[test]
fn oversized_length_prefix_gets_error_and_server_survives() {
    let srv = server(ServeConfig::loopback(TOKEN, 128));
    let mut client =
        DeviceClient::connect(srv.local_addr(), TOKEN, 2).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // a hostile 4 GiB length prefix: rejected before allocation
    client.send_raw(&u32::MAX.to_le_bytes()).unwrap();
    match client.recv().unwrap() {
        wire::Frame::Error { code, .. } =>
            assert_eq!(code, wire::ERR_PROTOCOL),
        f => panic!("expected ERROR, got {f:?}"),
    }
    // the server as a whole is unharmed: a fresh session streams fine
    let mut c2 = DeviceClient::connect(srv.local_addr(), TOKEN, 3).unwrap();
    c2.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let stream = qstream(5, REC_LEN);
    let got = send_lockstep(&mut c2, &stream, true);
    assert_eq!(got.len(), 1);
    c2.finish().unwrap();
    let stats = srv.shutdown();
    assert!(stats.protocol_errors >= 1);
}

#[test]
fn zero_length_prefix_is_malformed() {
    let srv = server(ServeConfig::loopback(TOKEN, 128));
    let mut client =
        DeviceClient::connect(srv.local_addr(), TOKEN, 4).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    client.send_raw(&0u32.to_le_bytes()).unwrap();
    match client.recv().unwrap() {
        wire::Frame::Error { code, .. } =>
            assert_eq!(code, wire::ERR_PROTOCOL),
        f => panic!("expected ERROR, got {f:?}"),
    }
    srv.shutdown();
}

#[test]
fn truncated_frame_then_half_close_is_handled() {
    let srv = server(ServeConfig::loopback(TOKEN, 128));
    let mut sock = TcpStream::connect(srv.local_addr()).unwrap();
    wire::write_frame(&mut sock, &wire::Frame::Hello {
        token: TOKEN.into(), device_id: 5 }).unwrap();
    sock.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    match wire::read_frame(&mut sock, wire::MAX_FRAME_BYTES).unwrap() {
        wire::Frame::Welcome { .. } => {}
        f => panic!("expected WELCOME, got {f:?}"),
    }
    // promise 100 bytes, deliver 10, walk away mid-frame
    sock.write_all(&100u32.to_le_bytes()).unwrap();
    sock.write_all(&[wire::TAG_SAMPLES_I8; 10]).unwrap();
    sock.shutdown(Shutdown::Write).unwrap();
    // server treats the dangling frame as a peer disappearance (an IO
    // condition, not a protocol offense) and tears the session down
    loop {
        match wire::read_frame(&mut sock, wire::MAX_FRAME_BYTES) {
            Ok(wire::Frame::Goodbye) | Err(_) => break,
            Ok(_) => {}
        }
    }
    // wait for teardown, then confirm the listener still serves
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while srv.stats().conns > 0 {
        assert!(std::time::Instant::now() < deadline, "conn never closed");
        std::thread::sleep(Duration::from_millis(5));
    }
    let c2 = DeviceClient::connect(srv.local_addr(), TOKEN, 6).unwrap();
    c2.finish().unwrap();
    let stats = srv.shutdown();
    assert_eq!(stats.protocol_errors, 0,
               "truncation + half-close is IO, not a protocol error");
}

#[test]
fn busy_backpressure_sheds_then_recovers() {
    let mut cfg = ServeConfig::loopback(TOKEN, 128);
    cfg.max_inflight_samples = 256; // below one full frame
    let srv = server(cfg);
    let mut client =
        DeviceClient::connect(srv.local_addr(), TOKEN, 9).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

    // a single frame above the whole budget ALWAYS sheds
    let stream = qstream(77, REC_LEN);
    client.send_i8(&stream[..300]).unwrap();
    match client.recv().unwrap() {
        wire::Frame::Busy { dropped } => assert_eq!(dropped, 300),
        f => panic!("expected BUSY, got {f:?}"),
    }

    // the session is still healthy: stream the window in chunks the
    // budget accepts. BUSY is synchronous (the reader sheds before
    // reading the next frame), so a short read timeout with no BUSY
    // means the chunk was accepted.
    client.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
    let mut got: Vec<[i32; 2]> = Vec::new();
    for chunk in stream.chunks(128) {
        loop {
            client.send_i8(chunk).unwrap();
            match client.recv() {
                Ok(wire::Frame::Busy { .. }) => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Ok(wire::Frame::Diagnosis { logits, .. }) => {
                    got.push(logits);
                    break;
                }
                Ok(f) => panic!("unexpected frame: {f:?}"),
                Err(e) if e.is_io() => break, // timeout: accepted
                Err(e) => panic!("{e}"),
            }
        }
    }
    // the four 128-sample chunks complete exactly one 512 window
    if got.is_empty() {
        client.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        loop {
            match client.recv().unwrap() {
                wire::Frame::Diagnosis { logits, .. } => {
                    got.push(logits);
                    break;
                }
                wire::Frame::Busy { .. } | wire::Frame::Stats { .. } => {}
                f => panic!("unexpected frame: {f:?}"),
            }
        }
    }
    client.finish().unwrap();
    let stats = srv.shutdown();
    assert!(stats.busy_frames >= 1);

    // shed means SHED: the oracle must see only the delivered samples
    let mut oracle = StreamSession::new(compiled(), 128).unwrap();
    let want: Vec<[i32; 2]> = oracle.push_quantized(&stream)
        .into_iter().map(|d| d.logits).collect();
    assert_eq!(got, want);
}

#[test]
fn connection_cap_rejects_with_capacity_error() {
    let mut cfg = ServeConfig::loopback(TOKEN, 128);
    cfg.max_conns = 1;
    let srv = server(cfg);
    let c1 = DeviceClient::connect(srv.local_addr(), TOKEN, 10).unwrap();
    let err = DeviceClient::connect(srv.local_addr(), TOKEN, 11)
        .unwrap_err();
    assert!(err.to_string()
                .contains(&format!("code {}", wire::ERR_CAPACITY)),
            "{err}");
    c1.finish().unwrap();
    let stats = srv.shutdown();
    assert_eq!(stats.rejected_capacity, 1);
}

#[test]
fn per_ip_rate_limit_rejects_bursts() {
    let mut cfg = ServeConfig::loopback(TOKEN, 128);
    cfg.per_ip_burst = 2;
    cfg.per_ip_window = Duration::from_secs(30);
    let srv = server(cfg);
    let c1 = DeviceClient::connect(srv.local_addr(), TOKEN, 12).unwrap();
    let c2 = DeviceClient::connect(srv.local_addr(), TOKEN, 13).unwrap();
    let err = DeviceClient::connect(srv.local_addr(), TOKEN, 14)
        .unwrap_err();
    assert!(err.to_string()
                .contains(&format!("code {}", wire::ERR_RATE_LIMITED)),
            "{err}");
    c1.finish().unwrap();
    c2.finish().unwrap();
    let stats = srv.shutdown();
    assert_eq!(stats.rejected_rate, 1);
}

#[test]
fn graceful_drain_delivers_goodbye() {
    let srv = server(ServeConfig::loopback(TOKEN, 128));
    let mut client =
        DeviceClient::connect(srv.local_addr(), TOKEN, 15).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // stream half a window so the session is mid-flight at drain
    client.send_i8(&qstream(3, 200)).unwrap();
    let stats = srv.shutdown();
    assert_eq!(stats.conns, 0, "drain must close every connection");
    // the drain half-closed our read side server-side; the last frame
    // the server pushes before the socket dies is GOODBYE
    let mut saw_goodbye = false;
    loop {
        match client.recv() {
            Ok(wire::Frame::Goodbye) => {
                saw_goodbye = true;
                break;
            }
            Ok(_) => {}
            Err(_) => break,
        }
    }
    assert!(saw_goodbye, "drain must announce itself with GOODBYE");
}

#[test]
fn stats_subscription_pushes_snapshots() {
    let mut cfg = ServeConfig::loopback(TOKEN, 128);
    cfg.stats_interval = Duration::from_millis(30);
    let srv = server(cfg);
    let mut client =
        DeviceClient::connect(srv.local_addr(), TOKEN, 16).unwrap();
    client.subscribe_stats().unwrap();
    client.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // the first snapshot can race the worker registering the session —
    // accept a few frames until it shows up
    let mut seen = false;
    for _ in 0..10 {
        match client.recv().unwrap() {
            wire::Frame::Stats { sessions, .. } if sessions >= 1 => {
                seen = true;
                break;
            }
            wire::Frame::Stats { .. } => {}
            f => panic!("expected STATS, got {f:?}"),
        }
    }
    assert!(seen, "no snapshot ever counted our session");
    client.finish().unwrap();
    srv.shutdown();
}

#[test]
fn loadgen_small_fleet_is_bit_exact() {
    // the bench shape in miniature: a handful of concurrent devices
    // through the whole wire path, oracle-checked
    let srv = server(ServeConfig::loopback(TOKEN, 128));
    let rep = loadgen(srv.local_addr(), TOKEN, compiled(), 8, 3).unwrap();
    let stats = srv.shutdown();
    assert_eq!(rep.connect_failures, 0);
    assert_eq!(rep.mismatches, 0);
    assert_eq!(rep.total_windows, 8 * 3);
    assert!(stats.peak_sessions >= 8,
            "all 8 devices must be concurrent (peak {})",
            stats.peak_sessions);
}

#[test]
fn scenario_loadgen_streams_adversarial_waveforms_bit_exact() {
    // the --scenario lane: analog perturbed streams through the full
    // server-side front end, still oracle-exact
    let srv = server(ServeConfig::loopback(TOKEN, 256));
    let rep = loadgen_scenario(srv.local_addr(), TOKEN, compiled(), 4, 3,
                               Family::Powerline, 0xA5).unwrap();
    let stats = srv.shutdown();
    assert_eq!(rep.scenario, Some("powerline"));
    assert_eq!(rep.connect_failures, 0);
    assert_eq!(rep.mismatches, 0,
               "streamed diagnoses must match the offline oracle");
    assert_eq!(rep.total_windows, 4 * 3);
    assert_eq!(stats.evicted_slow + stats.evicted_super, 0);
}

/// A worker panic mid-session must surface to the client as an
/// explicit supervisor-eviction ERROR — not silence — and the server
/// must respawn the worker and keep serving fresh sessions.
#[test]
fn worker_panic_evicts_with_supervisor_code_and_server_recovers() {
    let hop = 128;
    let mut cfg = ServeConfig::loopback(TOKEN, hop);
    cfg.workers = 1; // every device id lands on the faulty shard
    cfg.fault_plan = FaultPlan {
        seed: 11,
        faults: vec![PlannedFault {
            at_window: 0,
            kind: FaultKind::WorkerPanic { shard: 0, after: 1 },
        }],
    };
    let srv = server(cfg);
    let mut client =
        DeviceClient::connect(srv.local_addr(), TOKEN, 1).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let frame_len = client.frame_len as usize;
    let stream = qstream(0xEB_11, frame_len);
    client.send_i8(&stream).unwrap();
    // the diagnosis is queued BEFORE the injected panic fires…
    match client.recv().unwrap() {
        wire::Frame::Diagnosis { window, .. } => assert_eq!(window, 0),
        f => panic!("expected the pre-panic diagnosis, got {f:?}"),
    }
    // …then the supervisor evicts the session with the explicit code
    let mut saw = None;
    loop {
        match client.recv() {
            Ok(wire::Frame::Error { code, .. }) => {
                saw = Some(code);
                break;
            }
            Ok(wire::Frame::Stats { .. }) => {}
            Ok(f) => panic!("unexpected frame: {f:?}"),
            Err(_) => break, // EOF also ends the session
        }
    }
    assert_eq!(saw, Some(wire::ERR_EVICTED),
               "eviction must name the supervisor code");
    // the respawned worker serves a fresh session normally
    let mut c2 = DeviceClient::connect(srv.local_addr(), TOKEN, 2).unwrap();
    c2.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    c2.send_i8(&qstream(0xEB_12, frame_len)).unwrap();
    assert!(matches!(c2.recv().unwrap(),
                     wire::Frame::Diagnosis { .. }));
    c2.finish().unwrap();
    let stats = srv.shutdown();
    assert_eq!(stats.worker_respawns, 1);
    assert_eq!(stats.evicted_super, 1);
    assert_eq!(stats.evicted_slow, 0);
}

/// The acceptance gate: an injected worker panic under live traffic
/// is survived end to end — the resilient client reconnects, replays,
/// and the caller sees every diagnosis window exactly once, in order,
/// bit-exact vs the offline oracle.
#[test]
fn resilient_device_survives_worker_panic_without_losing_windows() {
    let hop = 128;
    let mut cfg = ServeConfig::loopback(TOKEN, hop);
    cfg.workers = 1;
    cfg.fault_plan = FaultPlan {
        seed: 23,
        faults: vec![PlannedFault {
            at_window: 0,
            kind: FaultKind::WorkerPanic { shard: 0, after: 3 },
        }],
    };
    let srv = server(cfg);
    let mut dev =
        ResilientDevice::connect(srv.local_addr(), TOKEN, 7).unwrap();
    let frame_len = dev.frame_len();
    assert_eq!(dev.hop(), hop);
    let windows = 6;
    let stream = qstream(0xFA_17, frame_len + hop * (windows - 1));
    let mut got = Vec::new();
    let mut sent = 0usize;
    for w in 0..windows {
        let hi = if w == 0 { frame_len } else { sent + hop };
        got.extend(dev.push(&stream[sent..hi]).unwrap());
        sent = hi;
    }
    // exactly once, in order — no lost or duplicated windows
    assert_eq!(got.len(), windows);
    for (i, d) in got.iter().enumerate() {
        assert_eq!(d.window, i as u64);
    }
    assert!(dev.reconnects >= 1, "the fault must have forced a reconnect");
    assert!(dev.replayed_windows >= 1,
            "replay must have re-covered pre-fault windows");
    assert_eq!(dev.delivered(), windows as u64);

    // bit-exact vs the offline oracle over the identical stream
    let mut oracle = StreamSession::new(compiled(), hop).unwrap();
    let want: Vec<[i32; 2]> = oracle.push_quantized(&stream)
        .into_iter().map(|d| d.logits).collect();
    let have: Vec<[i32; 2]> = got.iter().map(|d| d.logits).collect();
    assert_eq!(have, want);

    dev.finish().unwrap();
    let stats = srv.shutdown();
    assert_eq!(stats.worker_respawns, 1);
    assert!(stats.evicted_super >= 1);
}
