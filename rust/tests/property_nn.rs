//! Property tests for the integer golden kernels: `nn::conv1d_int` and
//! `nn::requant` vs naive f64 references, over randomized shapes,
//! strides and bit-widths (2/4/8), including the `pad_same` edge cases
//! at stride > 1. All values stay far below 2^53, so the f64 reference
//! is exact and any disagreement is a real integer-kernel bug.

use va_accel::data::SplitMix64;
use va_accel::nn::{conv1d_int, pad_same, requant, QMAX, QMIN};

/// Naive f64 convolution with the same `[L, Cin]` / `[K, Cin, Cout]`
/// row-major layout (no skips, no tricks).
fn conv1d_ref_f64(a: &[i32], l: usize, cin: usize, w: &[i32], k: usize,
                  cout: usize, bias: &[i32], stride: usize) -> Vec<f64> {
    let lout = (l - k) / stride + 1;
    let mut out = vec![0.0f64; lout * cout];
    for lo in 0..lout {
        for co in 0..cout {
            let mut acc = bias[co] as f64;
            for kk in 0..k {
                for ci in 0..cin {
                    acc += a[(lo * stride + kk) * cin + ci] as f64
                        * w[(kk * cin + ci) * cout + co] as f64;
                }
            }
            out[lo * cout + co] = acc;
        }
    }
    out
}

fn random_weights(rng: &mut SplitMix64, n: usize, nbits: u32,
                  sparsity: f64) -> Vec<i32> {
    let qmax = (1i64 << (nbits - 1)) - 1;
    (0..n)
        .map(|_| {
            if rng.uniform() < sparsity {
                0
            } else {
                let v = 1 + (rng.next_u64() % qmax as u64) as i32;
                if rng.uniform() < 0.5 { -v } else { v }
            }
        })
        .collect()
}

#[test]
fn property_conv1d_int_matches_f64_reference() {
    for seed in 0..80u64 {
        let mut rng = SplitMix64::new(0xC0417 + seed);
        let k = [1, 2, 3, 5, 7][(rng.next_u64() % 5) as usize];
        let stride = 1 + (rng.next_u64() as usize) % k.min(3);
        let cin = 1 + (rng.next_u64() % 4) as usize;
        let cout = 1 + (rng.next_u64() % 6) as usize;
        let nbits = [2u32, 4, 8][(rng.next_u64() % 3) as usize];
        let l = k + stride * (rng.next_u64() % 20) as usize
            + (rng.next_u64() % stride as u64) as usize;
        let a: Vec<i32> = (0..l * cin)
            .map(|_| (rng.next_u64() % 255) as i32 - 127)
            .collect();
        let sparsity = rng.uniform();
        let w = random_weights(&mut rng, k * cin * cout, nbits, sparsity);
        let bias: Vec<i32> = (0..cout)
            .map(|_| (rng.next_u64() % 2000) as i32 - 1000)
            .collect();
        let got = conv1d_int(&a, l, cin, &w, k, cout, &bias, stride);
        let want = conv1d_ref_f64(&a, l, cin, &w, k, cout, &bias, stride);
        assert_eq!(got.len(), want.len(), "seed {seed}");
        for (i, (&g, &r)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g as f64, r, "seed {seed} idx {i} \
                       (k={k} s={stride} cin={cin} cout={cout} nbits={nbits})");
        }
    }
}

#[test]
fn property_padded_conv_matches_f64_reference_at_stride_gt_one() {
    // the pad_same → conv1d_int chain the model/sim actually run:
    // total pad k - stride, split low-biased left, Lout = floor(L/s)
    for seed in 0..60u64 {
        let mut rng = SplitMix64::new(0xFAD + seed);
        let k = [2, 3, 5, 7][(rng.next_u64() % 4) as usize];
        let stride = 2 + (rng.next_u64() as usize) % (k - 1).max(1);
        let stride = stride.min(k);
        let cin = 1 + (rng.next_u64() % 3) as usize;
        let cout = 1 + (rng.next_u64() % 4) as usize;
        let l = stride * (1 + (rng.next_u64() % 16) as usize);
        let a: Vec<i32> = (0..l * cin)
            .map(|_| (rng.next_u64() % 255) as i32 - 127)
            .collect();
        let w = random_weights(&mut rng, k * cin * cout, 8, 0.4);
        let bias = vec![0i32; cout];

        let padded = pad_same(&a, l, cin, k, stride);
        let lp = padded.len() / cin;
        // geometry: total pad k - stride, left share (k - stride) / 2
        let p = k - stride;
        assert_eq!(lp, l + p, "seed {seed}");
        for i in 0..(p / 2) * cin {
            assert_eq!(padded[i], 0, "seed {seed}: left pad must be zero");
        }
        for i in (p / 2 + l) * cin..padded.len() {
            assert_eq!(padded[i], 0, "seed {seed}: right pad must be zero");
        }
        assert_eq!(&padded[(p / 2) * cin..(p / 2 + l) * cin], &a[..],
                   "seed {seed}: payload must be unshifted");

        let got = conv1d_int(&padded, lp, cin, &w, k, cout, &bias, stride);
        let want = conv1d_ref_f64(&padded, lp, cin, &w, k, cout, &bias, stride);
        let lout = (lp - k) / stride + 1;
        assert_eq!(lout, l / stride, "seed {seed}: 'same' geometry");
        for (&g, &r) in got.iter().zip(&want) {
            assert_eq!(g as f64, r, "seed {seed}");
        }
    }
}

#[test]
fn pad_same_edge_cases() {
    // k == stride → no padding at all
    let a: Vec<i32> = (1..=6).collect();
    assert_eq!(pad_same(&a, 6, 1, 2, 2), a);
    assert_eq!(pad_same(&a, 6, 1, 3, 3), a);
    // odd total pad is right-heavy: k=5, s=2 → pad 3 = (1, 2)
    assert_eq!(pad_same(&[9], 1, 1, 5, 2), vec![0, 9, 0, 0]);
    // multichannel rows pad as whole samples: k=3, s=2 → pad 1 = (0, 1)
    assert_eq!(pad_same(&[1, 2, 3, 4], 2, 2, 3, 2), vec![1, 2, 3, 4, 0, 0]);
}

#[test]
fn property_requant_matches_f64_reference() {
    for seed in 0..40u64 {
        let mut rng = SplitMix64::new(0x2E9 + seed);
        for _ in 0..200 {
            let acc = (rng.next_u64() % (1u64 << 29)) as i64 - (1 << 28);
            let acc = acc as i32;
            let m0 = (rng.next_u64() % (1u64 << 24)) as i32;
            let shift = [4u32, 8, 16, 24][(rng.next_u64() % 4) as usize];
            let relu = rng.uniform() < 0.5;
            // exact f64 model: floor((acc*m0 + 2^(shift-1)) / 2^shift),
            // then ReLU, then clamp — products stay < 2^53 so every
            // intermediate is exactly representable
            let t = acc as f64 * m0 as f64 + (1u64 << (shift - 1)) as f64;
            let mut want = (t / (1u64 << shift) as f64).floor();
            if relu && want < 0.0 {
                want = 0.0;
            }
            let want = want.clamp(QMIN as f64, QMAX as f64);
            let got = requant(acc, m0, shift, relu);
            assert_eq!(got as f64, want,
                       "seed {seed} acc={acc} m0={m0} shift={shift} relu={relu}");
        }
    }
}

#[test]
fn requant_is_monotone_and_bounded_across_bitwidth_scales() {
    // monotonicity in the accumulator for every shift used by the
    // 2/4/8-bit layer profiles, and output always inside [QMIN, QMAX]
    for shift in [8u32, 16, 24] {
        let m0 = 1 << (shift.min(23));
        let mut prev = i32::MIN;
        for acc in (-5000..5000).step_by(7) {
            let r = requant(acc, m0, shift, false);
            assert!(r >= prev, "shift {shift} acc {acc}");
            assert!((QMIN..=QMAX).contains(&r));
            prev = r;
        }
    }
}
