//! Static-vs-dynamic counter equality: the compile-time cost model
//! (`compiler::StaticCost`, stamped by the fast engine) must be
//! bit-identical to what the counted reference engine measures, for
//! every seed, precision profile, stride, engagement geometry and
//! zero-skip mode. This is the invariant that lets the serving hot
//! path skip event counting entirely.

use va_accel::arch::ChipConfig;
use va_accel::compiler::compile;
use va_accel::data::{fixtures, Dataset, SplitMix64};
use va_accel::nn::{QLayer, QuantModel};
use va_accel::sim;
use va_accel::REC_LEN;

/// The static counters and the counted engine agree on `cm`, and the
/// counted counters do not depend on the input (zero-skip operates on
/// weights, never activations).
fn assert_static_equals_counted(cm: &va_accel::compiler::CompiledModel,
                                xs: &[Vec<i8>], tag: &str) {
    for (i, x) in xs.iter().enumerate() {
        let counted = sim::run_counted(cm, x);
        assert_eq!(cm.static_cost.counters, counted.counters,
                   "{tag}: static != counted on recording {i}");
        let fast = sim::run(cm, x);
        assert_eq!(fast.logits, counted.logits, "{tag}: recording {i}");
        assert_eq!(fast.counters, counted.counters, "{tag}: recording {i}");
    }
}

#[test]
fn paper_shaped_fixture_models_seed_swept() {
    for seed in [1u64, 0xBEEF, 0x5EED_CAB1, 42] {
        let m = fixtures::quant_model(seed);
        let cm = compile(&m, &ChipConfig::paper_1d(), REC_LEN).unwrap();
        let ds = Dataset::synthesize(seed ^ 0xA5, 1, 0.5);
        assert_static_equals_counted(&cm, &ds.x[..2], &format!("seed {seed}"));
    }
}

#[test]
fn dense_mode_and_full_array_engagement() {
    let m = fixtures::quant_model(7);
    let ds = Dataset::synthesize(7, 1, 0.5);
    for (zero_skip, full) in [(false, false), (false, true), (true, true)] {
        let mut cfg = if full { ChipConfig::paper() } else { ChipConfig::paper_1d() };
        cfg.zero_skip = zero_skip;
        let cm = compile(&m, &cfg, REC_LEN).unwrap();
        assert_static_equals_counted(
            &cm, &ds.x[..1],
            &format!("zero_skip={zero_skip} full={full}"));
    }
}

/// Random small networks: random strides (incl. >1 with k > stride and
/// k == stride), kernel widths, precisions, sparsity levels, ragged
/// cout (padding lanes), and both zero-skip modes.
#[test]
fn random_models_seed_swept() {
    for seed in 0..25u64 {
        let mut rng = SplitMix64::new(0x57A7 + seed);
        let n_layers = 2 + (rng.next_u64() % 3) as usize;
        let mut layers = Vec::new();
        let mut cin = 1 + (rng.next_u64() % 3) as usize;
        let cin0 = cin;
        let l_in = 24 + 8 * (rng.next_u64() % 4) as usize;
        let mut l = l_in;
        for li in 0..n_layers {
            let k = [1, 2, 3, 5][(rng.next_u64() % 4) as usize];
            // 'same' padding needs k >= stride; halving needs even L
            let stride = if k > 1 && l % 2 == 0 && l >= 2 * k {
                1 + (rng.next_u64() % 2) as usize
            } else {
                1
            };
            let is_head = li == n_layers - 1;
            let cout = if is_head { 2 } else { 1 + (rng.next_u64() % 24) as usize };
            let nbits = [8u32, 4, 2, 1][(rng.next_u64() % 4) as usize];
            let qmax = if nbits == 1 { 1 } else { (1 << (nbits - 1)) - 1 };
            let sparsity = rng.uniform();
            let w: Vec<i32> = (0..k * cin * cout)
                .map(|_| {
                    if rng.uniform() < sparsity {
                        0
                    } else {
                        let v = 1 + (rng.next_u64() % qmax as u64) as i32;
                        if rng.uniform() < 0.5 { -v } else { v }
                    }
                })
                .collect();
            layers.push(QLayer {
                k, stride, cin, cout,
                relu: !is_head,
                nbits,
                shift: if is_head { 0 } else { 24 },
                s_in: 1.0, s_out: 1.0,
                w,
                bias: (0..cout).map(|_| (rng.next_u64() % 200) as i32 - 100).collect(),
                m0: (0..cout).map(|_| 1 + (rng.next_u64() % (1 << 24)) as i32).collect(),
            });
            l /= stride;
            cin = cout;
        }
        let model = QuantModel { layers };
        let mut cfg = if rng.uniform() < 0.5 {
            ChipConfig::paper_1d()
        } else {
            ChipConfig::paper()
        };
        cfg.zero_skip = rng.uniform() < 0.7;
        let cm = compile(&model, &cfg, l_in).unwrap();
        let xs: Vec<Vec<i8>> = (0..2)
            .map(|_| (0..l_in * cin0)
                .map(|_| ((rng.next_u64() % 255) as i32 - 127) as i8)
                .collect())
            .collect();
        assert_static_equals_counted(&cm, &xs, &format!("seed {seed}"));
    }
}

/// Explicit stride edge cases: k == stride (zero padding) and stride 1
/// with wide kernels, ragged cout (cout % m != 0 → padding lanes), and
/// a fully-pruned lane.
#[test]
fn stride_and_padding_lane_edges() {
    let model = QuantModel { layers: vec![
        // k == stride: pad = 0
        QLayer { k: 2, stride: 2, cin: 1, cout: 5, relu: true, nbits: 4,
                 shift: 24, s_in: 1.0, s_out: 1.0,
                 w: vec![1, 0, -2, 3, 0,
                         0, 2, 0, -1, 0], // lane 4 fully pruned
                 bias: vec![1, 2, 3, 4, 5], m0: vec![1 << 22; 5] },
        // stride 1, k 3: pad 2
        QLayer { k: 3, stride: 1, cin: 5, cout: 2, relu: false, nbits: 8,
                 shift: 0, s_in: 1.0, s_out: 1.0,
                 w: (0..30).map(|i| if i % 3 == 0 { 0 } else { i - 15 }).collect(),
                 bias: vec![0, 0], m0: vec![0, 0] },
    ]};
    for zero_skip in [true, false] {
        let mut cfg = ChipConfig::paper_1d();
        cfg.zero_skip = zero_skip;
        let cm = compile(&model, &cfg, 16).unwrap();
        let xs: Vec<Vec<i8>> = vec![
            (0..16).map(|i| (i * 13 % 160) as i8).collect(),
            vec![0i8; 16], // all-zero input must not change counters
        ];
        assert_static_equals_counted(&cm, &xs,
                                     &format!("edges zero_skip={zero_skip}"));
    }
}
