//! Coordinator edge cases: batcher policy boundaries, partial vote
//! groups, flush semantics, tie-breaking, and shutdown with in-flight
//! recordings — for the single-worker `Service` and the sharded
//! `Fleet` alike.

use std::time::{Duration, Instant};

use va_accel::coordinator::{Backend, Batcher, BatcherConfig, Fleet,
                            FleetConfig, Pipeline, Service, Voter};
use va_accel::nn::{majority_vote, QLayer, QuantModel};
use va_accel::REC_LEN;

/// Backend whose sign tracks the input mean: x > 0 → VA.
fn sign_backend() -> Backend {
    Backend::golden(QuantModel { layers: vec![
        QLayer { k: 1, stride: 1, cin: 1, cout: 2, relu: false, nbits: 8,
                 shift: 0, s_in: 1.0, s_out: 1.0, w: vec![-1, 1],
                 bias: vec![0, 0], m0: vec![0, 0] },
    ]})
}

fn rec(va: bool) -> Vec<i8> {
    vec![if va { 1i8 } else { -1i8 }; REC_LEN]
}

// ------------------------------------------------------------ batcher

#[test]
fn batcher_poll_caps_at_max_batch_and_preserves_remainder() {
    let mut b = Batcher::new(BatcherConfig {
        max_batch: 3,
        max_age: Duration::from_secs(3600),
    });
    for v in 0..7i8 {
        b.push(vec![v]);
    }
    let first = b.poll(Instant::now()).expect("full batch");
    assert_eq!(first.ids, vec![0, 1, 2]);
    let second = b.poll(Instant::now()).expect("still a full batch queued");
    assert_eq!(second.ids, vec![3, 4, 5]);
    // one young recording left: held, then drained
    assert!(b.poll(Instant::now()).is_none());
    assert_eq!(b.len(), 1);
    let rest = b.drain().expect("drain remainder");
    assert_eq!(rest.ids, vec![6]);
    assert!(b.is_empty());
}

#[test]
fn batcher_deadline_flushes_partial_batch_only_when_aged() {
    let mut b = Batcher::new(BatcherConfig {
        max_batch: 100,
        max_age: Duration::from_millis(50),
    });
    b.push(vec![1]);
    b.push(vec![2]);
    assert!(b.poll(Instant::now()).is_none(), "young partials are held");
    let later = Instant::now() + Duration::from_millis(60);
    let batch = b.poll(later).expect("aged partial must flush");
    assert_eq!(batch.ids, vec![0, 1]);
}

#[test]
fn batcher_ids_stay_monotone_across_drains() {
    let mut b = Batcher::new(BatcherConfig {
        max_batch: 2,
        max_age: Duration::from_secs(3600),
    });
    b.push(vec![1]);
    b.drain().unwrap();
    b.push(vec![2]);
    b.push(vec![3]);
    let batch = b.poll(Instant::now()).unwrap();
    assert_eq!(batch.ids, vec![1, 2], "ids continue after a drain");
}

// -------------------------------------------------------------- voter

#[test]
fn voter_partial_group_stays_pending() {
    let mut v = Voter::new(4);
    assert!(v.push(true).is_none());
    assert!(v.push(true).is_none());
    assert!(v.push(false).is_none());
    assert_eq!(v.pending(), 3);
    assert_eq!(v.completed(), 0);
    // the 4th detection completes the episode; pending resets
    let ep = v.push(false).expect("complete group");
    assert_eq!(ep.votes, vec![true, true, false, false]);
    assert_eq!(v.pending(), 0);
    assert_eq!(v.completed(), 1);
}

#[test]
fn voter_even_group_ties_resolve_to_non_va() {
    let mut v = Voter::new(2);
    assert!(v.push(true).is_none());
    let ep = v.push(false).unwrap();
    assert!(!ep.is_va, "1/2 tie must not shock");
    // and the standalone vote primitive agrees
    assert!(!majority_vote(&[true, false]).is_va);
    assert!(!majority_vote(&[true, true, false, false]).is_va);
    assert!(majority_vote(&[true, true, true, false]).is_va);
}

#[test]
fn voter_episode_indices_count_completed_groups_only() {
    let mut v = Voter::new(2);
    assert!(v.push(true).is_none());
    let e0 = v.push(true).unwrap();
    assert!(v.push(false).is_none());
    let e1 = v.push(false).unwrap();
    assert_eq!(e0.index, 0);
    assert_eq!(e1.index, 1);
    assert!(v.push(true).is_none()); // pending forever — never indexed
    assert_eq!(v.completed(), 2);
}

// ----------------------------------------------------------- pipeline

#[test]
fn pipeline_flush_does_not_fabricate_partial_episodes() {
    let mut p = Pipeline::new(sign_backend(), BatcherConfig {
        max_batch: 8,
        max_age: Duration::from_secs(3600),
    }, 4);
    p.push_recording(rec(true)).unwrap();
    p.push_recording(rec(true)).unwrap();
    // flush forces the batcher through the backend, but only 2 of 4
    // votes exist: no diagnosis may surface
    let d = p.flush().unwrap();
    assert!(d.is_empty(), "partial vote group must stay pending");
    assert_eq!(p.stats.recordings, 2);
    assert_eq!(p.stats.episodes, 0);
    // completing the group (plus flush) emits exactly one episode
    p.push_recording(rec(true)).unwrap();
    p.push_recording(rec(false)).unwrap();
    let d = p.flush().unwrap();
    assert_eq!(d.len(), 1);
    assert!(d[0].episode.is_va, "3/4 VA majority");
    assert_eq!(p.stats.episodes, 1);
}

// ------------------------------------------------------------ service

#[test]
fn service_shutdown_processes_in_flight_recordings() {
    let p = Pipeline::new(sign_backend(), BatcherConfig {
        max_batch: 1,
        max_age: Duration::ZERO,
    }, 3);
    let svc = Service::spawn(p);
    let h = svc.handle();
    for _ in 0..6 {
        h.submit_recording(rec(true)).unwrap();
    }
    // no flush, no recv: shutdown must still run everything queued
    // (the worker drains its channel before honoring Shutdown)
    let p = svc.shutdown();
    assert_eq!(p.stats.recordings, 6);
    assert_eq!(p.stats.episodes, 2);
    assert_eq!(p.stats.va_episodes, 2);
}

#[test]
fn service_flush_emits_only_complete_groups() {
    let p = Pipeline::new(sign_backend(), BatcherConfig {
        max_batch: 16,
        max_age: Duration::from_secs(3600),
    }, 2);
    let svc = Service::spawn(p);
    let h = svc.handle();
    h.submit_recording(rec(false)).unwrap();
    h.submit_recording(rec(false)).unwrap();
    h.submit_recording(rec(true)).unwrap(); // dangling half-group
    h.flush().unwrap();
    let d = svc.recv().expect("one complete episode");
    assert!(!d.episode.is_va);
    assert!(svc.try_recv().is_none(), "half group must not diagnose");
    let p = svc.shutdown();
    assert_eq!(p.stats.recordings, 3);
    assert_eq!(p.stats.episodes, 1);
}

// -------------------------------------------------------------- fleet

#[test]
fn fleet_partial_vote_groups_survive_flush_and_shutdown() {
    let fleet = Fleet::spawn(
        FleetConfig {
            batcher: BatcherConfig { max_batch: 2, max_age: Duration::ZERO },
            vote_group: 4,
            ..FleetConfig::new(1)
        },
        |_| Ok(sign_backend()),
    )
    .unwrap();
    let h = fleet.handle();
    for _ in 0..3 {
        h.submit_labeled(rec(true), true).unwrap();
    }
    h.flush().unwrap();
    let report = fleet.shutdown();
    assert_eq!(report.recordings, 3);
    assert_eq!(report.episodes, 0, "3/4 of a vote group is no episode");
    // unscored: the recordings never reached a diagnosis
    assert_eq!(report.ep_confusion.total(), 0);
}

#[test]
fn fleet_tie_breaks_to_non_va_per_shard() {
    let fleet = Fleet::spawn(
        FleetConfig {
            batcher: BatcherConfig { max_batch: 2, max_age: Duration::ZERO },
            vote_group: 2,
            ..FleetConfig::new(1)
        },
        |_| Ok(sign_backend()),
    )
    .unwrap();
    let h = fleet.handle();
    h.submit(rec(true)).unwrap();
    h.submit(rec(false)).unwrap();
    h.flush().unwrap();
    let (_, d) = fleet.recv().expect("episode");
    assert!(!d.episode.is_va, "1/1 tie must resolve to non-VA");
    let report = fleet.shutdown();
    assert_eq!(report.episodes, 1);
    assert_eq!(report.va_episodes, 0);
}

#[test]
fn fleet_shutdown_with_queued_work_drains_everything() {
    let fleet = Fleet::spawn(
        FleetConfig {
            batcher: BatcherConfig { max_batch: 4, max_age: Duration::ZERO },
            vote_group: 2,
            ..FleetConfig::new(3)
        },
        |_| Ok(sign_backend()),
    )
    .unwrap();
    let h = fleet.handle();
    for i in 0..60 {
        h.submit_labeled(rec(i % 2 == 0), i % 2 == 0).unwrap();
    }
    let report = fleet.shutdown(); // no flush: drain is implicit
    assert_eq!(report.recordings, 60);
    assert_eq!(report.rec_confusion.total(), 60);
    assert_eq!(report.rec_confusion.accuracy(), 1.0,
               "sign backend must score perfectly against its labels");
}
