//! Integration tests for the adversarial scenario harness: numeric
//! perturbation invariants (each family does what its physics says,
//! measured against its clean twin), StreamSession reset/determinism
//! (the state-clearing contract the recalibration loop rides on), and
//! the recalibration logit-invariance contract.

use std::sync::Arc;

use va_accel::arch::ChipConfig;
use va_accel::compiler::{compile, CompiledModel};
use va_accel::coordinator::{RecalConfig, StreamSession};
use va_accel::data::{fixtures, Generator, RhythmClass, Scenario};
use va_accel::REC_LEN;

fn rms(v: &[f64]) -> f64 {
    (v.iter().map(|x| x * x).sum::<f64>() / v.len().max(1) as f64).sqrt()
}

/// Per-sample perturbation extracted against the clean twin (both
/// streams share the identical base rhythm samples by construction).
fn perturbation(sc: &Scenario) -> Vec<f64> {
    let a = sc.synthesize();
    let b = sc.clean_twin().expect("scenario must have a twin").synthesize();
    assert_eq!(a.samples.len(), b.samples.len());
    a.samples.iter().zip(&b.samples).map(|(x, y)| x - y).collect()
}

#[test]
fn sensor_noise_rms_tracks_intensity() {
    // 16*512 = 8192 gaussian samples: the sample RMS sits within a
    // few percent of the configured intensity
    for &intensity in &[0.6, 1.2] {
        let d = perturbation(&Scenario::sensor_noise(21, 16, intensity));
        let r = rms(&d);
        assert!(r > 0.8 * intensity && r < 1.2 * intensity,
                "intensity {intensity}: perturbation rms {r}");
    }
}

#[test]
fn powerline_injects_inband_tone() {
    // 1.5-amplitude AM'd 50 Hz tone: rms ≈ 1.5/√2·1.02 ≈ 1.08
    let d = perturbation(&Scenario::powerline(22, 16, 1.5));
    let r = rms(&d);
    assert!(r > 0.8 && r < 1.4, "powerline rms {r}");
    // and it really is inside the passband: a 50 Hz tone at 250 Hz
    // crosses zero every 2.5 samples — high sign-change density
    let flips = d.windows(2)
        .filter(|w| w[0].signum() != w[1].signum())
        .count();
    assert!(flips as f64 / d.len() as f64 > 0.25, "flips {flips}");
}

#[test]
fn baseline_wander_is_large_but_slow() {
    let d = perturbation(&Scenario::baseline_wander(23, 16, 3.0));
    let r = rms(&d);
    // two-tone: √(9/2 + 1.8²/2) ≈ 2.47
    assert!(r > 1.8 && r < 3.2, "wander rms {r}");
    // sub-passband: consecutive-sample steps are tiny relative to the
    // excursion (max slope ≈ 0.039/sample at these frequencies)
    let max_step = d.windows(2)
        .map(|w| (w[1] - w[0]).abs())
        .fold(0.0f64, f64::max);
    assert!(max_step < 0.2, "wander step {max_step}");
}

#[test]
fn amplitude_drift_attenuates_the_tail() {
    let sc = Scenario::amplitude_drift(24, 16, 0.2);
    let a = sc.synthesize();
    let b = sc.clean_twin().unwrap().synthesize();
    let last = 15 * REC_LEN..16 * REC_LEN;
    let ratio = rms(&a.samples[last.clone()]) / rms(&b.samples[last]);
    // the gain ramp spans 0.25→0.20 across the final segment
    assert!(ratio > 0.15 && ratio < 0.35, "tail gain {ratio}");
    // while the head is still near unity
    let head = 0..REC_LEN;
    let head_ratio = rms(&a.samples[head.clone()]) / rms(&b.samples[head]);
    assert!(head_ratio > 0.9 && head_ratio < 1.05, "head gain {head_ratio}");
}

fn model() -> Arc<CompiledModel> {
    let m = fixtures::quant_model(0x5E55);
    Arc::new(compile(&m, &ChipConfig::paper_1d(), REC_LEN).unwrap())
}

fn stream_for(seed: u64) -> Vec<f64> {
    let (raw, _) = Generator::new(seed).stream(&[
        (RhythmClass::Nsr, 1), (RhythmClass::Vt, 2), (RhythmClass::Vf, 1),
    ]);
    raw
}

/// After `reset()`, a session must be bit-identical to a fresh one:
/// same quantized stream, same detections — the biquad/AGC/engine
/// state-clearing contract.
#[test]
fn session_reset_equals_fresh_session() {
    let cm = model();
    let hop = 64;
    let a = stream_for(31);
    let b = stream_for(32);

    // quantizer contract: push A, reset, quantize B == fresh quantize B
    let mut used = StreamSession::new(Arc::clone(&cm), hop).unwrap();
    used.push(&a);
    used.reset();
    assert_eq!(used.pending(), 0);
    let q_used = used.quantize(&b);
    let q_fresh = StreamSession::new(Arc::clone(&cm), hop)
        .unwrap()
        .quantize(&b);
    assert_eq!(q_used, q_fresh, "quantized windows must be bit-identical");

    // full-session contract: detections after reset == fresh, pushed
    // in different chunkings to also exercise the framing state
    let mut used = StreamSession::new(Arc::clone(&cm), hop).unwrap();
    for chunk in a.chunks(173) {
        used.push(chunk);
    }
    used.reset();
    let mut dets_used = Vec::new();
    for chunk in b.chunks(89) {
        dets_used.extend(used.push(chunk));
    }
    let mut fresh = StreamSession::new(Arc::clone(&cm), hop).unwrap();
    let dets_fresh = fresh.push(&b);
    assert_eq!(dets_used.len(), dets_fresh.len());
    for (i, (x, y)) in dets_used.iter().zip(&dets_fresh).enumerate() {
        assert_eq!(x.logits, y.logits, "window {i}");
        assert_eq!(x.is_va, y.is_va, "window {i}");
    }
}

#[test]
fn session_reset_clears_recalibration_state() {
    let cm = model();
    let hop = 64;
    let cfg = RecalConfig { horizon: 4, warmup: 4,
                            ..RecalConfig::default() };
    let b = stream_for(33);

    let mut used =
        StreamSession::with_recalibration(Arc::clone(&cm), hop, cfg.clone())
            .unwrap();
    used.push(&stream_for(34));
    let warmed = used.recal_stats().unwrap();
    assert!(warmed.windows > 0, "loop must have observed windows");
    used.reset();
    let cleared = used.recal_stats().unwrap();
    assert_eq!(cleared.windows, 0);
    assert_eq!(cleared.reference, None);

    let dets_used = used.push(&b);
    let mut fresh =
        StreamSession::with_recalibration(Arc::clone(&cm), hop, cfg).unwrap();
    let dets_fresh = fresh.push(&b);
    assert_eq!(dets_used.len(), dets_fresh.len());
    for (i, (x, y)) in dets_used.iter().zip(&dets_fresh).enumerate() {
        assert_eq!(x.logits, y.logits, "window {i}");
        assert_eq!(x.is_va, y.is_va, "window {i}");
    }
}

/// The recalibration loop may only move the decision threshold: logits
/// from an armed session are bit-identical to a plain session's, and
/// with a dead zone wider than any margin the verdicts match argmax
/// exactly too.
#[test]
fn recalibration_never_touches_logits() {
    let cm = model();
    let hop = 128;
    let raw = Scenario::amplitude_drift(35, 8, 0.2).synthesize().samples;

    let mut plain = StreamSession::new(Arc::clone(&cm), hop).unwrap();
    let base = plain.push(&raw);
    assert!(!base.is_empty());

    // tight loop (may flip verdicts, must not touch logits)
    let mut armed = StreamSession::with_recalibration(
        Arc::clone(&cm), hop,
        RecalConfig { horizon: 4, warmup: 4, dead_zone: 0.0,
                      ..RecalConfig::default() })
        .unwrap();
    let tight = armed.push(&raw);
    assert_eq!(tight.len(), base.len());
    for (i, (t, b)) in tight.iter().zip(&base).enumerate() {
        assert_eq!(t.logits, b.logits, "window {i}");
    }

    // guarded loop (dead zone > total margin spread): verdicts too
    let margins: Vec<i64> = base.iter()
        .map(|d| d.logits[1] as i64 - d.logits[0] as i64)
        .collect();
    let spread = (margins.iter().max().unwrap()
        - margins.iter().min().unwrap()) as f64;
    let mut guarded = StreamSession::with_recalibration(
        Arc::clone(&cm), hop,
        RecalConfig { dead_zone: spread + 1.0, ..RecalConfig::default() })
        .unwrap();
    let g = guarded.push(&raw);
    for (i, (x, y)) in g.iter().zip(&base).enumerate() {
        assert_eq!(x.logits, y.logits, "window {i}");
        assert_eq!(x.is_va, y.is_va,
                   "window {i}: dead-zoned loop must equal argmax");
    }
    assert_eq!(guarded.recal_stats().unwrap().compensated_windows, 0);
}
