//! End-to-end pipeline integration: raw analog stream → diagnosis,
//! across backends, plus accuracy reproduction on the build corpus.
//!
//! Structural tests (counter accumulation, threaded service flow,
//! fleet serving) are hermetic — they run on the fixture model.
//! Accuracy-dependent tests need the TRAINED `weights.bin` and are
//! `#[ignore]`d with a reason when that artifact is what they measure.

use va_accel::arch::ChipConfig;
use va_accel::compiler::compile;
use va_accel::coordinator::{Backend, BatcherConfig, Fleet, FleetConfig,
                            Pipeline, Service};
use va_accel::data::{fixtures, load_eval, Generator, RhythmClass};
use va_accel::nn::QuantModel;
use va_accel::{ARTIFACT_DIR, REC_LEN, VOTE_GROUP};

fn model() -> QuantModel {
    fixtures::model_or_artifact()
}

#[test]
#[ignore = "accuracy requires the trained weights.bin (`make artifacts`)"]
fn streaming_diagnosis_on_synthetic_episodes() {
    let m = QuantModel::load(format!("{ARTIFACT_DIR}/weights.bin")).unwrap();
    let mut p = Pipeline::paper(Backend::golden(m));
    let mut gen = Generator::new(11);
    let mut correct = 0;
    let plan = [RhythmClass::Nsr, RhythmClass::Vt, RhythmClass::Vf,
                RhythmClass::Svt, RhythmClass::Vf, RhythmClass::Nsr];
    let mut diagnoses = Vec::new();
    for &class in &plan {
        let (samples, _) = gen.stream(&[(class, VOTE_GROUP)]);
        diagnoses.extend(p.push_samples(&samples).unwrap());
    }
    diagnoses.extend(p.flush().unwrap());
    assert_eq!(diagnoses.len(), plan.len());
    for (d, &class) in diagnoses.iter().zip(&plan) {
        if d.episode.is_va == class.is_va() {
            correct += 1;
        }
    }
    assert!(correct >= 5, "episode accuracy {correct}/6");
    assert_eq!(p.stats.recordings, (plan.len() * VOTE_GROUP) as u64);
}

#[test]
fn streaming_pipeline_emits_one_diagnosis_per_episode() {
    // hermetic variant of the above: the diagnosis PLUMBING (framing,
    // batching, voting, episode accounting) on the fixture model —
    // accuracy is not asserted, random weights predict what they will
    let mut p = Pipeline::paper(Backend::golden(model()));
    let mut gen = Generator::new(11);
    let plan = [RhythmClass::Nsr, RhythmClass::Vt, RhythmClass::Vf];
    let mut diagnoses = Vec::new();
    for &class in &plan {
        let (samples, _) = gen.stream(&[(class, VOTE_GROUP)]);
        diagnoses.extend(p.push_samples(&samples).unwrap());
    }
    diagnoses.extend(p.flush().unwrap());
    assert_eq!(diagnoses.len(), plan.len());
    for d in &diagnoses {
        assert_eq!(d.detections.len(), VOTE_GROUP);
        assert_eq!(d.episode.votes.len(), VOTE_GROUP);
    }
    assert_eq!(p.stats.recordings, (plan.len() * VOTE_GROUP) as u64);
    assert_eq!(p.stats.episodes, plan.len() as u64);
}

#[test]
fn chipsim_backend_through_pipeline_accumulates_counters() {
    let m = model();
    let cm = compile(&m, &ChipConfig::paper_1d(), REC_LEN).unwrap();
    let mut p = Pipeline::new(Backend::chipsim(cm), BatcherConfig {
        max_batch: 2, max_age: std::time::Duration::ZERO,
    }, 2);
    let mut gen = Generator::new(5);
    for _ in 0..2 {
        let rec = gen.recording(RhythmClass::Vt);
        p.push_recording(rec.quantized()).unwrap();
    }
    p.flush().unwrap();
    assert!(p.sim_counters.total_cycles() > 0,
            "chipsim pipeline must accumulate cycle counters");
    assert_eq!(p.stats.recordings, 2);
    assert!(p.latency.count() > 0);
}

#[test]
#[ignore = "accuracy requires the trained weights.bin + eval.bin (`make artifacts`)"]
fn accuracy_reproduces_paper_shape_on_eval_corpus() {
    // The paper's §3 accuracy claims: per-recording ~92.35 %, voted
    // diagnostic 99.95 % / precision 99.88 % / recall 99.84 %. On the
    // synthetic substitute we assert the *shape*: per-recording in the
    // 85–100 % band, and voting must IMPROVE on per-recording accuracy
    // with high precision/recall.
    let m = QuantModel::load(format!("{ARTIFACT_DIR}/weights.bin")).unwrap();
    let ds = load_eval(format!("{ARTIFACT_DIR}/eval.bin")).unwrap();
    let truth = ds.va_labels();
    let backend = Backend::golden(m);
    let (rec, ep) = Pipeline::evaluate(&backend, &ds.x, &truth, VOTE_GROUP).unwrap();
    assert!(rec.accuracy() > 0.85 && rec.accuracy() <= 1.0,
            "per-recording acc {}", rec.accuracy());
    assert!(ep.accuracy() >= rec.accuracy(),
            "voting must not hurt: {} vs {}", ep.accuracy(), rec.accuracy());
    assert!(ep.accuracy() > 0.97, "diagnostic acc {}", ep.accuracy());
    assert!(ep.precision() > 0.95, "diagnostic precision {}", ep.precision());
    assert!(ep.recall() > 0.95, "diagnostic recall {}", ep.recall());
}

#[test]
fn threaded_service_with_golden_backend() {
    let svc = Service::spawn(Pipeline::paper(Backend::golden(model())));
    let h = svc.handle();
    let mut gen = Generator::new(21);
    let (samples, _) = gen.stream(&[(RhythmClass::Vf, VOTE_GROUP)]);
    h.submit_samples(samples).unwrap();
    h.flush().unwrap();
    let d = svc.recv().expect("diagnosis");
    assert_eq!(d.detections.len(), VOTE_GROUP);
    let p = svc.shutdown();
    assert_eq!(p.stats.episodes, 1);
}

#[test]
fn fleet_with_chipsim_shards_serves_corpus() {
    // end-to-end hermetic fleet check with per-shard compiled models:
    // every recording diagnosed exactly once, counters accumulate on
    // every shard that did work, latency recorded fleet-wide
    let m = model();
    let cfg = ChipConfig::paper_1d();
    let ds = fixtures::eval_corpus(77, 6); // 24 recordings
    let fleet = Fleet::spawn(
        FleetConfig {
            batcher: BatcherConfig {
                max_batch: 4,
                max_age: std::time::Duration::ZERO,
            },
            vote_group: VOTE_GROUP,
            ..FleetConfig::new(2)
        },
        {
            let m = m.clone();
            let cfg = cfg.clone();
            move |_| Ok(Backend::chipsim(compile(&m, &cfg, REC_LEN)?))
        },
    )
    .unwrap();
    let h = fleet.handle();
    for (x, t) in ds.x.iter().zip(ds.va_labels()) {
        h.submit_labeled(x.clone(), t).unwrap();
    }
    h.flush().unwrap();
    let report = fleet.shutdown();
    assert_eq!(report.recordings, ds.len() as u64);
    assert_eq!(report.rec_confusion.total(), ds.len() as u64);
    assert!(report.sim_counters.total_cycles() > 0,
            "fleet must aggregate shard simulator counters");
    assert!(report.latency.count() > 0);
    for s in &report.shards {
        if s.processed > 0 {
            assert!(s.sim_counters.total_cycles() > 0, "shard {}", s.shard);
            assert!(s.latency.count() > 0, "shard {}", s.shard);
        }
    }
}
