//! Packed weight-stream arena: the safety net for the flat-SoA weight
//! memory (`compiler::PackedStreams`) and the 8-wide packed tile
//! kernel (`arch::tile_block_packed`) that replaced the per-lane
//! `Vec<Vec<LaneWork>>` layout on every inference path.
//!
//! Three families of pins:
//! 1. the arena itself — ranges tight/ordered, lane views reproduce
//!    the reference per-channel packing, padding lanes empty;
//! 2. execution — fast == counted == golden (logits) and static ==
//!    counted (counters) over `PackedStreams`, seed-swept across both
//!    fixtures (paper-shaped and the ragged live=1 model) and both
//!    zero-skip modes: packing moves memory, never events;
//! 3. the kernels — `tile_block_packed` == per-lane staged == gather
//!    reference, and the position-major head readout
//!    (`nn::global_avgpool_stripes`) == the per-lane strided walk.

use va_accel::arch::{lane_block, lane_block_packed, stage_window_block,
                     tile_block_packed, ChipConfig};
use va_accel::compiler::{compile, pack_layer};
use va_accel::data::{fixtures, SplitMix64};
use va_accel::nn::{avg_round, global_avgpool_stripes};
use va_accel::sim::{self, ScratchArena};
use va_accel::REC_LEN;

/// Random i8 recordings of `len` samples.
fn recordings(rng: &mut SplitMix64, n: usize, len: usize) -> Vec<Vec<i8>> {
    (0..n)
        .map(|_| (0..len)
            .map(|_| ((rng.next_u64() % 255) as i32 - 127) as i8)
            .collect())
        .collect()
}

#[test]
fn arena_reproduces_reference_per_channel_packing() {
    // For every layer of both fixtures: lane (t, l) of the arena must
    // hold exactly channel t·m+l's non-zero (select, weight) pairs in
    // window order, ranges must tile the arena tightly in lane order,
    // and the last tile's padding lanes must be empty with zero bias.
    let m = ChipConfig::paper_1d().m;
    for (model, tag) in [(fixtures::quant_model(0x9AC5), "paper"),
                         (fixtures::ragged_model(0x9AC5), "ragged")] {
        for (li, ly) in model.layers.iter().enumerate() {
            let p = pack_layer(ly, m);
            assert_eq!(p.m(), m);
            assert_eq!(p.ch_tiles(), ly.cout.div_ceil(m), "{tag} layer {li}");
            let mut nnz = 0usize;
            let mut expect_off = 0usize;
            for t in 0..p.ch_tiles() {
                for lane in 0..m {
                    let co = t * m + lane;
                    let v = p.lane(t, lane);
                    let (off, len) = p.tile_ranges(t)[lane];
                    assert_eq!(off as usize, expect_off,
                               "{tag} layer {li} co {co}: range not tight");
                    expect_off += len as usize;
                    if co >= ly.cout {
                        assert!(v.is_empty(),
                                "{tag} layer {li}: padding lane {co} not empty");
                        assert_eq!(p.tile_biases(t)[lane], 0);
                        continue;
                    }
                    assert_eq!(p.tile_biases(t)[lane], ly.bias[co]);
                    // reference packing: window order, zeros skipped
                    let mut want: Vec<(u32, i32)> = Vec::new();
                    for k in 0..ly.k {
                        for ci in 0..ly.cin {
                            let w = ly.w[(k * ly.cin + ci) * ly.cout + co];
                            if w != 0 {
                                want.push(((k * ly.cin + ci) as u32, w));
                            }
                        }
                    }
                    let got: Vec<(u32, i32)> = v.selects.iter().copied()
                        .zip(v.weights.iter().copied()).collect();
                    assert_eq!(got, want, "{tag} layer {li} co {co}");
                    nnz += v.len();
                }
            }
            assert_eq!(expect_off, p.selects().len(), "{tag} layer {li}");
            assert_eq!(nnz as u64, p.nnz(), "{tag} layer {li}");
            assert_eq!(nnz, ly.nnz(), "{tag} layer {li}");
        }
    }
}

#[test]
fn storage_accounting_pins_physical_and_logical_on_the_paper_fixture() {
    // the paper's storage metric (logical: every nnz weight at its
    // layer's nbits + its select signal) vs what the host arena
    // physically holds (sub-byte weight words + u32 selects). Both are
    // pinned layer by layer so neither can silently drift.
    let model = fixtures::quant_model(0x57AB1E);
    let cm = compile(&model, &ChipConfig::paper_1d(), REC_LEN).unwrap();
    let mut logical_bits = 0u64;
    let mut physical_bytes = 0u64;
    for (li, (layer, ly)) in cm.layers.iter()
        .zip(&model.layers)
        .enumerate()
    {
        let ps = &layer.packed;
        let nnz = ps.nnz();
        let wbits = ly.nbits.max(2) as u64;
        let per_word = 32 / wbits;
        // physical = packed weight words + one u32 select per nnz
        let want_words = nnz.div_ceil(per_word);
        assert_eq!(ps.weight_words().len() as u64, want_words,
                   "layer {li}: packed word count");
        assert_eq!(ps.arena_bytes(), 4 * (want_words + nnz),
                   "layer {li}: physical arena bytes");
        // the decoded i32 mirror is accounted separately — it is the
        // counted/static path's view, not part of the packed arena
        assert_eq!(ps.mirror_bytes(), 4 * nnz, "layer {li}");
        logical_bits += ps.storage_bits;
        physical_bytes += ps.arena_bytes();
    }
    assert_eq!(logical_bits, cm.weight_storage_bits);
    assert_eq!(physical_bytes, cm.weight_arena_bytes());
    assert_eq!(cm.compressed_bytes(), logical_bits.div_ceil(8));
    // physical (word-granular) can never undercut logical (bit-granular)
    assert!(cm.weight_arena_bytes() >= cm.compressed_bytes(),
            "physical {} < logical {}", cm.weight_arena_bytes(),
            cm.compressed_bytes());
}

#[test]
fn seed_swept_bitexact_fast_counted_golden_over_packed_streams() {
    // Execution over the flat arena: fast (packed tile kernel) ==
    // counted (SPE walk over borrowed lane views) == golden (no chip
    // model at all), on both fixtures including the ragged model's
    // live=1 partial stripes.
    let mut rng = SplitMix64::new(0x9AC4ED);
    for seed in [3u64, 0xFEED, 0x9AC4_57A7] {
        for (model, len, tag) in [
            (fixtures::quant_model(seed), REC_LEN, "paper"),
            (fixtures::ragged_model(seed), fixtures::RAGGED_LEN, "ragged"),
        ] {
            let cm = compile(&model, &ChipConfig::paper_1d(), len).unwrap();
            let mut fast = ScratchArena::for_model(&cm);
            let mut counted = ScratchArena::for_model(&cm);
            for (i, x) in recordings(&mut rng, 2, len).iter().enumerate() {
                let golden = model.forward(x);
                let f = sim::run_scratch(&cm, x, &mut fast);
                let c = sim::run_counted_scratch(&cm, x, &mut counted);
                assert_eq!(f.logits, golden, "{tag} seed {seed} rec {i}: fast");
                assert_eq!(c.logits, golden,
                           "{tag} seed {seed} rec {i}: counted");
                assert_eq!(f.counters, c.counters,
                           "{tag} seed {seed} rec {i}: static != counted");
            }
        }
    }
}

#[test]
fn packing_moves_no_events_dense_and_sparse() {
    // static == counted across zero-skip modes and forced tile
    // parallelism: the stream arena is a memory layout, so every
    // event count (MACs, cycles, fetches, SPad traffic) must be
    // byte-identical to what the counted engine measures walking the
    // same streams through borrowed views.
    let mut rng = SplitMix64::new(0xE7E275);
    for (model, len, tag) in [
        (fixtures::quant_model(0x5EED), REC_LEN, "paper"),
        (fixtures::ragged_model(0x5EED), fixtures::RAGGED_LEN, "ragged"),
    ] {
        for zero_skip in [true, false] {
            let mut cfg = ChipConfig::paper_1d();
            cfg.zero_skip = zero_skip;
            let cm = compile(&model, &cfg, len).unwrap();
            for (i, x) in recordings(&mut rng, 2, len).iter().enumerate() {
                let fast = sim::run(&cm, x);
                let counted = sim::run_counted(&cm, x);
                let par = sim::run_parallel(&cm, x);
                assert_eq!(fast.counters, counted.counters,
                           "{tag} zs={zero_skip} rec {i}: static != counted");
                assert_eq!(par.counters, counted.counters,
                           "{tag} zs={zero_skip} rec {i}: parallel != serial");
                assert_eq!(fast.logits, counted.logits,
                           "{tag} zs={zero_skip} rec {i}");
                assert_eq!(cm.static_cost.counters, counted.counters,
                           "{tag} zs={zero_skip} rec {i}: compile-time cost");
            }
        }
    }
}

#[test]
fn tile_kernel_matches_per_lane_and_gather_kernels() {
    // tile_block_packed over a real layer's arena == lane_block_packed
    // per lane == the staging-free gather kernel, on every position
    // block of every tile (partial live < m tiles included).
    let model = fixtures::ragged_model(0x71C7);
    let cm = compile(&model, &ChipConfig::paper_1d(),
                     fixtures::RAGGED_LEN).unwrap();
    let mut rng = SplitMix64::new(0x71C7ED);
    const B: usize = 8;
    for (li, layer) in cm.layers.iter().enumerate() {
        let sched = &cm.schedule.layers[li];
        let ps = &layer.packed;
        let step = layer.stride * layer.cin;
        let wlen = sched.window_len;
        let padded: Vec<i32> = (0..sched.l_padded * layer.cin)
            .map(|_| (rng.next_u64() % 255) as i32 - 127)
            .collect();
        let mut stage = vec![0i32; wlen * B];
        let mut lo = 0usize;
        while lo + B <= sched.lout {
            stage_window_block::<B>(&padded, lo * step, step, wlen,
                                    &mut stage);
            for (t, st) in sched.stripes.iter().enumerate() {
                let mut stripe = vec![0i32; sched.lout * st.live];
                tile_block_packed::<B>(ps.selects(), ps.weights(),
                                       ps.tile_ranges(t), ps.tile_biases(t),
                                       &stage, &mut stripe, lo, st.live);
                for lane in 0..st.live {
                    let v = ps.lane(t, lane);
                    let bias = ps.tile_biases(t)[lane];
                    let a: [i32; B] = lane_block_packed(v.selects, v.weights,
                                                        &stage, bias);
                    let g: [i32; B] =
                        lane_block(&v, &padded, lo * step, step, bias);
                    assert_eq!(a, g, "layer {li} tile {t} lane {lane} lo {lo}");
                    for p in 0..B {
                        assert_eq!(stripe[(lo + p) * st.live + lane], a[p],
                                   "layer {li} tile {t} lane {lane} p {p}");
                    }
                }
            }
            lo += B;
        }
    }
}

#[test]
fn positional_head_readout_matches_strided_walk() {
    // the fused position-major head pooling must be bit-exact with
    // the per-lane strided walk it replaced, on real head geometries
    // (both fixtures) and on a synthetic partial-stripe layout
    for (model, len, tag) in [
        (fixtures::quant_model(0xFACE), REC_LEN, "paper"),
        (fixtures::ragged_model(0xFACE), fixtures::RAGGED_LEN, "ragged"),
    ] {
        let cm = compile(&model, &ChipConfig::paper_1d(), len).unwrap();
        let sched = cm.schedule.layers.last().unwrap();
        let cout = model.layers.last().unwrap().cout;
        let head_len = sched.lout;
        let mut rng = SplitMix64::new(0xD00D);
        let out: Vec<i32> = (0..sched.out_len)
            .map(|_| (rng.next_u64() as i32) >> 8)
            .collect();
        // the pre-fusion readout: per-lane strided walk + avg_round
        let mut want = vec![0i32; cout];
        for st in &sched.stripes {
            for lane in 0..st.live {
                let sum: i64 = (0..head_len)
                    .map(|lo| out[st.offset + lo * st.live + lane] as i64)
                    .sum();
                want[st.base_co + lane] = avg_round(sum, head_len);
            }
        }
        assert_eq!(global_avgpool_stripes(&sched.stripes, &out, head_len,
                                          cout),
                   want, "{tag}");
    }
}

#[test]
fn chipsim_parallel_backend_is_bit_exact_with_chipsim() {
    // the big-chip throughput backend runs the identical integer
    // function and stamps the identical static counters
    use va_accel::coordinator::Backend;
    let model = fixtures::quant_model(0xB16C);
    let cm = compile(&model, &ChipConfig::paper_1d(), REC_LEN).unwrap();
    let serial = Backend::chipsim(cm.clone());
    let par = Backend::chipsim_parallel(cm);
    let ds = fixtures::eval_corpus(0xB16C, 3);
    let (a, ca) = serial.infer_with_counters(&ds.x).unwrap();
    let (b, cb) = par.infer_with_counters(&ds.x).unwrap();
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.logits, y.logits, "recording {i}");
        assert_eq!(x.is_va, y.is_va, "recording {i}");
    }
    assert_eq!(ca.unwrap(), cb.unwrap());
}
