//! Tier-1: incremental streaming inference is bit-exact vs full
//! recompute — seed-swept, across hop sizes from 1 to `frame_len`
//! inclusive (hop == frame_len degenerates to the per-window path),
//! on both the paper-geometry fixture and the ragged fixture whose
//! every layer ends in a partial stripe.

use std::sync::Arc;

use va_accel::arch::ChipConfig;
use va_accel::compiler::{compile, CompiledModel, StreamPlan};
use va_accel::coordinator::StreamSession;
use va_accel::data::fixtures;
use va_accel::data::SplitMix64;
use va_accel::sim::{run_scratch, ScratchArena, StreamingEngine};
use va_accel::REC_LEN;

fn qstream(seed: u64, n: usize) -> Vec<i8> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| rng.range(-127.0, 128.0) as i8).collect()
}

/// Drive `windows` windows at `hop` through a `StreamingEngine` in
/// ragged chunks and assert every emitted window is bit-exact with
/// `run_scratch` on the same stream slice.
fn assert_stream_bitexact(cm: &Arc<CompiledModel>, seed: u64, hop: usize,
                          windows: usize) {
    let frame_len = cm.static_cost.input_len;
    let n = frame_len + hop * (windows - 1);
    let stream = qstream(seed, n);
    let mut eng = StreamingEngine::new(Arc::clone(cm), hop).unwrap();
    let mut outs = Vec::new();
    // ragged pushes: prime numbers straddle every window boundary
    let mut rng = SplitMix64::new(seed ^ 0x9E37);
    let mut at = 0usize;
    while at < stream.len() {
        let step = 1 + rng.range(0.0, 97.0) as usize;
        let end = (at + step).min(stream.len());
        outs.extend(eng.push(&stream[at..end]));
        at = end;
    }
    assert_eq!(outs.len(), windows, "seed {seed} hop {hop}");
    let mut arena = ScratchArena::for_model(cm);
    for (i, o) in outs.iter().enumerate() {
        let w = &stream[i * hop..i * hop + frame_len];
        let full = run_scratch(cm, w, &mut arena);
        assert_eq!(o.logits, full.logits, "seed {seed} hop {hop} window {i}");
        assert_eq!(o.predicted, full.predicted,
                   "seed {seed} hop {hop} window {i}");
    }
}

#[test]
fn paper_fixture_bitexact_across_hops_and_seeds() {
    // representative hops: aligned (full reuse chains), misaligned
    // (plan collapses early), boundary values 1 and frame_len
    let hops = [1usize, 2, 7, 32, 64, 128, 192, 256, 511, REC_LEN];
    for seed in [0xA1u64, 0xB2] {
        let m = fixtures::quant_model(seed);
        let cm = Arc::new(
            compile(&m, &ChipConfig::paper_1d(), REC_LEN).unwrap());
        for &hop in &hops {
            assert_stream_bitexact(&cm, seed.wrapping_mul(31) + hop as u64,
                                   hop, 4);
        }
    }
}

#[test]
fn small_model_bitexact_exhaustive_hops() {
    // a small geometry so EVERY hop in 1..=frame_len is affordable:
    // covers every alignment/collapse case of the fringe recursion
    let frame_len = 32usize;
    let m = fixtures::model_from_geometry(0xC0FFEE, &[
        (7, 2, 1, 16, 8),
        (5, 2, 16, 32, 4),
        (3, 2, 32, 16, 8),
        (1, 1, 16, 2, 8),
    ]);
    let cm = Arc::new(
        compile(&m, &ChipConfig::paper_1d(), frame_len).unwrap());
    for hop in 1..=frame_len {
        assert_stream_bitexact(&cm, 0x5EED + hop as u64, hop, 5);
    }
}

#[test]
fn ragged_fixture_bitexact_across_hops() {
    // every layer ends in a partial stripe (live < m): the carry
    // shift + fringe recompute must respect packed partial stripes
    let m = fixtures::ragged_model(0x7A66);
    let cm = Arc::new(
        compile(&m, &ChipConfig::paper_1d(), fixtures::RAGGED_LEN).unwrap());
    for hop in 1..=fixtures::RAGGED_LEN {
        assert_stream_bitexact(&cm, 0x11 + hop as u64, hop, 4);
    }
}

#[test]
fn aligned_hops_actually_reuse_columns() {
    let m = fixtures::quant_model(0xFA);
    let cm = Arc::new(
        compile(&m, &ChipConfig::paper_1d(), REC_LEN).unwrap());
    for hop in [32usize, 64, 128] {
        let stream = qstream(hop as u64, REC_LEN + hop * 3);
        let mut eng = StreamingEngine::new(Arc::clone(&cm), hop).unwrap();
        let _ = eng.push(&stream);
        let st = eng.stats();
        assert_eq!(st.windows, 4);
        assert!(st.carried_cols > 0, "hop {hop} must carry columns");
        // the engine's accounting must agree with the static plan:
        // 3 incremental windows × the plan's carried columns
        let plan = StreamPlan::of(&cm.schedule, hop);
        assert_eq!(st.carried_cols, 3 * plan.carried_cols() as u64,
                   "hop {hop}");
    }
    // hop == frame_len: the degenerate plan carries nothing
    let stream = qstream(9, REC_LEN * 3);
    let mut eng = StreamingEngine::new(Arc::clone(&cm), REC_LEN).unwrap();
    let _ = eng.push(&stream);
    assert_eq!(eng.stats().carried_cols, 0);
    assert_eq!(eng.stats().windows, 3);
}

#[test]
fn session_front_end_bitexact_on_generated_stream() {
    // end to end through the coordinator session: raw f64 IEGM stream,
    // continuous filter + running-RMS AGC, per-sample quantization,
    // delta-reuse engine — vs the per-window fast path on the
    // session's own quantized stream
    use va_accel::data::{Generator, RhythmClass};
    for seed in [3u64, 14] {
        let m = fixtures::quant_model(seed);
        let cm = Arc::new(
            compile(&m, &ChipConfig::paper_1d(), REC_LEN).unwrap());
        let (raw, _) = Generator::new(seed).stream(&[
            (RhythmClass::Vf, 1), (RhythmClass::Nsr, 1),
            (RhythmClass::Vt, 1),
        ]);
        let hop = 128;
        let qstream = StreamSession::new(Arc::clone(&cm), hop)
            .unwrap()
            .quantize(&raw);
        let mut sess = StreamSession::new(Arc::clone(&cm), hop).unwrap();
        let mut dets = Vec::new();
        for chunk in raw.chunks(313) {
            dets.extend(sess.push(chunk));
        }
        assert_eq!(dets.len(), (raw.len() - REC_LEN) / hop + 1);
        let mut arena = ScratchArena::for_model(&cm);
        for (i, d) in dets.iter().enumerate() {
            let w = &qstream[i * hop..i * hop + REC_LEN];
            let full = run_scratch(&cm, w, &mut arena);
            assert_eq!(d.logits.as_slice(), full.logits.as_slice(),
                       "seed {seed} window {i}");
        }
    }
}

#[test]
fn streaming_arena_reports_carry_slab() {
    let m = fixtures::quant_model(1);
    let cm = Arc::new(
        compile(&m, &ChipConfig::paper_1d(), REC_LEN).unwrap());
    let eng = StreamingEngine::new(Arc::clone(&cm), 32).unwrap();
    let st = eng.arena_stats();
    let total_out: usize =
        cm.schedule.layers.iter().map(|s| s.out_len).sum();
    assert!(st.carry_words >= total_out,
            "carry slab must hold every layer's stripes");
    // and the per-window arena never grows one
    assert_eq!(ScratchArena::for_model(&cm).stats().carry_words, 0);
}
