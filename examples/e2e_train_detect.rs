//! END-TO-END VALIDATION DRIVER (DESIGN.md §5).
//!
//! Proves every layer of the three-layer stack composes on a real
//! workload:
//!
//! 1. **Build products** — the model was trained, pruned (co-design,
//!    50 %), quantized (8-bit CMUL contract) and AOT-lowered by
//!    `make artifacts` (python, build time only). This driver consumes
//!    weights.bin + eval.bin + model_b*.hlo.txt and reports the
//!    training-time metrics recorded in qparams.json.
//! 2. **Bit-exactness** — runs the evaluation corpus through all three
//!    rust backends (PJRT/XLA artifact, golden integer model,
//!    cycle-accurate chip simulator) and asserts identical logits.
//! 3. **Paper metrics** — reproduces §3's table: per-recording
//!    accuracy, voted diagnostic accuracy/precision/recall, inference
//!    time, GOPS, average power, power density; prints paper-vs-ours.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train_detect
//! ```
//! The run is recorded in EXPERIMENTS.md.

use va_accel::arch::ChipConfig;
use va_accel::compiler::compile;
use va_accel::coordinator::{Backend, Pipeline};
use va_accel::data::load_eval;
use va_accel::nn::QuantModel;
use va_accel::power::{report, AreaModel, EnergyModel};
use va_accel::runtime::Executor;
use va_accel::sim;
use va_accel::{ARTIFACT_DIR, REC_LEN, VOTE_GROUP};

/// Minimal JSON number extraction (no serde in the offline build).
fn json_f64(src: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\":");
    let i = src.find(&pat)? + pat.len();
    let rest = src[i..].trim_start();
    let end = rest.find([',', '}', '\n'])?;
    rest[..end].trim().parse().ok()
}

fn main() -> anyhow::Result<()> {
    println!("══ e2e: train (build-time) → compile → detect ══\n");

    // ── stage 1: build products ──────────────────────────────────
    let model = QuantModel::load(format!("{ARTIFACT_DIR}/weights.bin"))?;
    let stats = model.stats(REC_LEN);
    let qp = std::fs::read_to_string(format!("{ARTIFACT_DIR}/qparams.json"))?;
    println!("[build] 8-layer 1-D FCN: {} params, {:.1}% sparse, {:.2} MMACs",
             stats.params, stats.sparsity * 100.0, stats.macs_dense as f64 / 1e6);
    if let (Some(f), Some(q)) = (json_f64(&qp, "acc_float"), json_f64(&qp, "acc_int")) {
        println!("[build] training: float acc {:.4} → pruned+QAT int acc {:.4}", f, q);
    }
    let ds = load_eval(format!("{ARTIFACT_DIR}/eval.bin"))?;
    println!("[build] eval corpus: {} recordings\n", ds.len());

    // ── stage 2: three-backend bit-exactness ─────────────────────
    let cm = compile(&model, &ChipConfig::paper_1d(), REC_LEN)?;
    let pjrt = Backend::pjrt(Executor::open(ARTIFACT_DIR)?);
    let n_check = 48.min(ds.len());
    let subset: Vec<Vec<i8>> = ds.x[..n_check].to_vec();
    let t0 = std::time::Instant::now();
    let pjrt_out = pjrt.infer(&subset)?;
    let pjrt_time = t0.elapsed();
    let mut mismatches = 0;
    for (i, x) in subset.iter().enumerate() {
        let golden = model.forward(x);
        let simr = sim::run(&cm, x);
        let pj = pjrt_out[i].logits.to_vec();
        if golden != simr.logits || golden != pj {
            mismatches += 1;
            eprintln!("  MISMATCH at {i}: golden {golden:?} sim {:?} pjrt {pj:?}",
                      simr.logits);
        }
    }
    println!("[exact] {} recordings × 3 backends (pjrt/golden/chipsim): {} mismatches",
             n_check, mismatches);
    assert_eq!(mismatches, 0, "bit-exactness violated");
    println!("[exact] PJRT wall time: {:.1} µs/recording (CPU)\n",
             pjrt_time.as_secs_f64() * 1e6 / n_check as f64);

    // ── stage 3: paper §3 metrics ─────────────────────────────────
    let truth = ds.va_labels();
    let golden = Backend::golden(model.clone());
    let (rec_conf, ep_conf) = Pipeline::evaluate(&golden, &ds.x, &truth, VOTE_GROUP)?;
    let r = sim::run(&cm, &ds.x[0]);
    let rep = report(&r.counters, &ChipConfig::paper_1d(),
                     &EnergyModel::lp40(), &AreaModel::lp40());
    println!("[paper-vs-ours]                         paper        ours");
    println!("  inference accuracy              :   92.35 %    {:>7.2} %",
             rec_conf.accuracy() * 100.0);
    println!("  diagnostic accuracy (vote of 6) :   99.95 %    {:>7.2} %",
             ep_conf.accuracy() * 100.0);
    println!("  diagnostic precision            :   99.88 %    {:>7.2} %",
             ep_conf.precision() * 100.0);
    println!("  diagnostic recall               :   99.84 %    {:>7.2} %",
             ep_conf.recall() * 100.0);
    println!("  inference time                  :   35 µs      {:>7.2} µs",
             rep.t_active_s * 1e6);
    println!("  performance                     :   150 GOPS   {:>7.1} GOPS", rep.gops);
    println!("  average power                   :   10.60 µW   {:>7.2} µW",
             rep.p_avg_w * 1e6);
    println!("  die area                        :   18.63 mm²  {:>7.2} mm²", rep.area_mm2);
    println!("  power density                   :   0.57 µW/mm² {:>6.3} µW/mm²",
             rep.density_uw_mm2);
    println!("\ne2e OK — all layers compose, numerics bit-exact, envelope reproduced");
    Ok(())
}
