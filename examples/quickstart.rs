//! Quickstart: load the AOT artifacts and classify one synthetic IEGM
//! recording on the PJRT runtime.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use va_accel::coordinator::FrontEnd;
use va_accel::data::{Generator, RhythmClass};
use va_accel::runtime::Executor;

fn main() -> anyhow::Result<()> {
    // 1. open the artifact set (produced once by `make artifacts`;
    //    python never runs at inference time)
    let exe = Executor::open(va_accel::ARTIFACT_DIR)?;
    println!("loaded artifacts: batch variants {:?}", exe.artifacts().batches);
    for (b, secs) in exe.warmup()? {
        println!("  compiled batch-{b} executable in {secs:.2}s");
    }

    // 2. synthesize one ventricular-tachycardia episode
    let mut gen = Generator::new(42);
    let rec = gen.recording(RhythmClass::Vt);

    // 3. the chip front end: 15-55 Hz band-pass, normalize, int8 ADC
    let mut fe = FrontEnd::new();
    let quantized = fe.push(&rec.raw).pop().expect("one full recording");

    // 4. inference
    let t0 = std::time::Instant::now();
    let out = exe.infer_one(&quantized)?;
    let dt = t0.elapsed();
    println!("\nground truth : {}", rec.class.name());
    println!("logits       : [non-VA {}, VA {}]", out.logits[0], out.logits[1]);
    println!("detection    : {}", if out.predicted_va { "VA — would trigger ICD therapy" } else { "non-VA" });
    println!("latency      : {:.1} µs (PJRT CPU)", dt.as_secs_f64() * 1e6);
    Ok(())
}
