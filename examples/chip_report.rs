//! Regenerates **Table 1** of the paper: comparison with previous
//! works, with our column produced by the cycle-accurate simulator +
//! 40 nm power/area model, and an extra accuracy column obtained by
//! running every baseline *algorithm* on the common synthetic task
//! (which the published chips never did — their accuracies are on
//! different datasets and are not comparable; ours are).
//!
//! ```bash
//! cargo run --release --example chip_report
//! ```

use va_accel::arch::ChipConfig;
use va_accel::baselines::{all_baselines, all_published_rows};
use va_accel::compiler::compile;
use va_accel::coordinator::{Backend, Pipeline};
use va_accel::data::{load_eval, Dataset};
use va_accel::metrics::Confusion;
use va_accel::nn::QuantModel;
use va_accel::power::{report, AreaModel, EnergyModel};
use va_accel::sim;
use va_accel::{ARTIFACT_DIR, REC_LEN, VOTE_GROUP};

fn fmt_freq(hz: f64) -> String {
    if hz >= 1e6 { format!("{:.0}M", hz / 1e6) }
    else if hz >= 1e3 { format!("{:.2}K", hz / 1e3) }
    else { format!("{hz:.0}") }
}

fn main() -> anyhow::Result<()> {
    // our column, from the simulator on the real workload
    let model = QuantModel::load(format!("{ARTIFACT_DIR}/weights.bin"))?;
    let cfg = ChipConfig::paper_1d();
    let cm = compile(&model, &cfg, REC_LEN)?;
    let ds = load_eval(format!("{ARTIFACT_DIR}/eval.bin"))?;
    let r = sim::run(&cm, &ds.x[0]);
    let rep = report(&r.counters, &cfg, &EnergyModel::lp40(), &AreaModel::lp40());
    let (rec_conf, _) = Pipeline::evaluate(&Backend::golden(model.clone()),
                                           &ds.x, &ds.va_labels(), VOTE_GROUP)?;

    // baselines trained on a common training corpus, scored on the
    // same eval corpus the CNN used
    println!("training baseline algorithms on the common task...");
    let tr = Dataset::synthesize(100, 96, 0.6);
    let mut base_acc = Vec::new();
    for mut b in all_baselines() {
        b.fit(&tr.x, &tr.va_labels());
        let mut c = Confusion::new();
        for (x, t) in ds.x.iter().zip(ds.va_labels()) {
            c.push(b.predict(x), t);
        }
        base_acc.push((b.name(), c.accuracy(), b.ops_per_inference()));
    }

    println!("\nTable 1: Comparison with Previous Works (reproduced)\n");
    println!("{:<22}{:>13}{:>13}{:>13}{:>13}{:>13}",
             "", "TBCAS'19[4]", "ICICM'22[5]", "MWSCAS'22[3]", "ISCAS'24[2]", "Our Work");
    let rows = all_published_rows();
    let g = |f: &dyn Fn(&va_accel::baselines::PublishedRow) -> String| -> Vec<String> {
        rows.iter().map(|r| f(r)).collect()
    };
    let print_row = |label: &str, cells: Vec<String>, ours: String| {
        print!("{label:<22}");
        for c in &cells {
            print!("{c:>13}");
        }
        println!("{ours:>13}");
    };
    print_row("Technology (nm)", g(&|r| r.tech_nm.to_string()), "40".into());
    print_row("Sparsity", g(&|r| if r.sparsity { "Yes" } else { "No" }.into()), "Yes".into());
    print_row("Feature", g(&|r| r.feature.into()), "1D-CNN".into());
    print_row("Type", g(&|_| "ASIC".into()), "ASIC (sim)".into());
    print_row("Area (mm²)",
              g(&|r| r.area_mm2.map(|a| format!("{a:.2}")).unwrap_or("N/A".into())),
              format!("{:.2}", rep.area_mm2));
    print_row("Voltage (V)", g(&|r| format!("{:.1}", r.voltage_v)), "1.14".into());
    print_row("Freq. (Hz)", g(&|r| fmt_freq(r.freq_hz)), fmt_freq(cfg.freq_hz));
    print_row("Power (µW)", g(&|r| format!("{:.2}", r.power_uw)),
              format!("{:.2}", rep.p_avg_w * 1e6));
    print_row("Power Density (µW/mm²)",
              g(&|r| r.density_uw_mm2.map(|d| format!("{d:.2}")).unwrap_or("N/A".into())),
              format!("{:.2}", rep.density_uw_mm2));
    // the extra, apples-to-apples rows only this reproduction can add
    let accs: Vec<String> = base_acc.iter().map(|(_, a, _)| format!("{:.2}%", a * 100.0)).collect();
    print_row("Acc. on common task", accs, format!("{:.2}%", rec_conf.accuracy() * 100.0));
    let ops: Vec<String> = base_acc.iter().map(|(_, _, o)| o.to_string()).collect();
    print_row("Ops per inference", ops, format!("{}", 2 * r.counters.total_macs_dense()));

    let best_prior = rows.iter().filter_map(|r| r.density_uw_mm2).fold(f64::INFINITY, f64::min);
    println!("\npower-density advantage vs best prior work: {:.2}× (paper claims 14.23×)",
             best_prior / rep.density_uw_mm2);
    println!("headline: {:.1} GOPS @ {:.2} µW, {:.2} µs/inference (paper: 150 GOPS @ 10.60 µW, 35 µs)",
             rep.gops, rep.p_avg_w * 1e6, rep.t_active_s * 1e6);
    Ok(())
}
