//! ICD monitor — the paper's Fig. 4 demo as a terminal application.
//!
//! A continuous synthetic IEGM stream (several rhythm episodes,
//! including a VF storm) flows through the threaded detection service;
//! the monitor prints each recording's waveform sketch, the
//! per-recording detections, and the voted episode diagnoses.
//!
//! ```bash
//! cargo run --release --example icd_monitor             # golden backend
//! cargo run --release --example icd_monitor -- pjrt     # AOT/PJRT backend
//! ```

use va_accel::coordinator::{Backend, Pipeline, Service};
use va_accel::data::{Generator, RhythmClass};
use va_accel::nn::QuantModel;
use va_accel::runtime::Executor;
use va_accel::{ARTIFACT_DIR, REC_LEN, VOTE_GROUP};

fn sparkline(samples: &[f64]) -> String {
    const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let max = samples.iter().fold(1e-9f64, |m, v| m.max(v.abs()));
    samples.chunks(REC_LEN / 64)
        .map(|c| {
            let v = c.iter().fold(0.0f64, |m, s| m.max(s.abs())) / max;
            GLYPHS[((v * 7.0).round() as usize).min(7)]
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let backend = match std::env::args().nth(1).as_deref() {
        Some("pjrt") => Backend::pjrt(Executor::open(ARTIFACT_DIR)?),
        _ => Backend::golden(QuantModel::load(format!("{ARTIFACT_DIR}/weights.bin"))?),
    };
    println!("ICD monitor — backend: {}\n", backend.name());
    let svc = Service::spawn(Pipeline::paper(backend));
    let h = svc.handle();

    // a session: sinus rhythm, an SVT run, a VT episode, a VF storm,
    // then recovery — 5 episodes × 6 recordings × 2.048 s
    let session = [
        (RhythmClass::Nsr, "baseline sinus rhythm"),
        (RhythmClass::Svt, "supraventricular tachycardia run"),
        (RhythmClass::Vt, "monomorphic VT episode"),
        (RhythmClass::Vf, "ventricular fibrillation storm"),
        (RhythmClass::Nsr, "post-therapy recovery"),
    ];
    let mut gen = Generator::new(2024);
    for (i, &(class, desc)) in session.iter().enumerate() {
        println!("── episode {i}: {desc} ({})", class.name());
        for _ in 0..VOTE_GROUP {
            let rec = gen.recording(class);
            println!("   {}", sparkline(&rec.raw));
            h.submit_samples(rec.raw)?;
        }
        h.flush()?;
        let d = svc.recv().expect("diagnosis");
        let votes: String = d.episode.votes.iter()
            .map(|&v| if v { 'V' } else { '·' })
            .collect();
        let verdict = if d.episode.is_va { "VA — THERAPY" } else { "non-VA" };
        let ok = if d.episode.is_va == class.is_va() { "✓" } else { "✗ MISDIAGNOSIS" };
        println!("   votes [{votes}] → {verdict}  {ok}\n");
    }

    let p = svc.shutdown();
    println!("session: {} recordings, {} episodes ({} VA)",
             p.stats.recordings, p.stats.episodes, p.stats.va_episodes);
    println!("inference latency: {}", p.latency.clone().summary());
    Ok(())
}
