//! Design-space exploration: what the paper's §3 remark "the chip size
//! can be scaled down as needed" looks like quantitatively.
//!
//! Sweeps array geometry (N×W×H×M), supply voltage, and SPad
//! organization; prints a Pareto table of area / average power /
//! inference time / effective GOPS for the 1-D VA workload.
//!
//! ```bash
//! cargo run --release --example design_space
//! ```

use va_accel::arch::{ChipConfig, SpadSharing};
use va_accel::compiler::compile;
use va_accel::data::{Generator, RhythmClass};
use va_accel::nn::QuantModel;
use va_accel::power::{report, AreaModel, EnergyModel};
use va_accel::sim;
use va_accel::{ARTIFACT_DIR, REC_LEN};

fn main() -> anyhow::Result<()> {
    let model = QuantModel::load(format!("{ARTIFACT_DIR}/weights.bin"))?;
    let mut gen = Generator::new(5);
    let x = gen.recording(RhythmClass::Vf).quantized();
    let am = AreaModel::lp40();

    println!("config                        PEs   area(mm²)  t_inf(µs)   GOPS   avg-µW  µW/mm²");
    println!("───────────────────────────────────────────────────────────────────────────────");
    // geometry sweep: scale the fabbed array down/up
    let geoms: [(usize, usize, usize, usize, &str); 5] = [
        (1, 1, 2, 16, "minimal implant (1×1×2×16)"),
        (1, 1, 4, 16, "small implant (1×1×4×16)"),
        (2, 1, 4, 16, "right-sized 1D die (2×1×4×16)"),
        (2, 4, 4, 16, "paper full die (2×4×4×16)"),
        (4, 4, 4, 16, "scaled-up (4×4×4×16)"),
    ];
    for (n, w, h, m, label) in geoms {
        let cfg = ChipConfig {
            n, w, h, m,
            cores_engaged: w,
            ..ChipConfig::paper()
        };
        let cm = compile(&model, &cfg, REC_LEN)?;
        let r = sim::run(&cm, &x);
        let rep = report(&r.counters, &cfg, &EnergyModel::lp40(), &am);
        println!("{label:<28} {:>4}  {:>9.2}  {:>9.2}  {:>6.1}  {:>6.2}  {:>6.3}",
                 cfg.total_pes(), rep.area_mm2, rep.t_active_s * 1e6,
                 rep.gops, rep.p_avg_w * 1e6, rep.density_uw_mm2);
    }

    println!("\nvoltage/frequency scaling (paper engagement, 128 PEs):");
    println!("  V      f(MHz)  t_inf(µs)   GOPS   avg-µW");
    for (v, f_mhz) in [(1.14, 400.0), (1.0, 300.0), (0.9, 200.0), (0.8, 120.0)] {
        let cfg = ChipConfig { freq_hz: f_mhz * 1e6, voltage: v,
                               ..ChipConfig::paper_1d() };
        let em = EnergyModel::lp40().at_voltage(v);
        let cm = compile(&model, &cfg, REC_LEN)?;
        let r = sim::run(&cm, &x);
        let rep = report(&r.counters, &cfg, &em, &am);
        println!("  {v:.2}   {f_mhz:>6.0}  {:>9.2}  {:>6.1}  {:>6.2}",
                 rep.t_active_s * 1e6, rep.gops, rep.p_avg_w * 1e6);
    }

    println!("\nSPad organization (the Fig. 2 design choice):");
    for (sharing, label) in [(SpadSharing::Shared, "shared SPad (paper)"),
                             (SpadSharing::PerPe, "per-PE SPads (Eyeriss-v2 style)")] {
        let cfg = ChipConfig { spad_sharing: sharing, ..ChipConfig::paper_1d() };
        let em = EnergyModel::lp40();
        let cm = compile(&model, &cfg, REC_LEN)?;
        let r = sim::run(&cm, &x);
        let e_uj = em.active_energy_j(&r.counters, &cfg) * 1e6;
        let rep = report(&r.counters, &cfg, &em, &am);
        println!("  {label:<34} active {e_uj:>6.3} µJ/inf, die {:>6.2} mm²",
                 rep.area_mm2);
    }
    Ok(())
}
